//! Bag-of-words corpus in a CSR-style **token arena** (DESIGN.md §Memory
//! layout).
//!
//! Storage is three flat, parallel arrays instead of a `Vec` of per-document
//! `Vec`s: one contiguous `tokens` arena, a `doc_offsets` prefix-sum
//! delimiting documents, and a flat `responses` array. The O(nnz) Gibbs
//! kernels stream tokens out of one allocation (no pointer chasing), and
//! shard partitioning hands workers [`CorpusView`]s — borrowed windows into
//! the shared arena — so the paper's shard-**setup** step copies no token
//! data at all (`parallel::comm` audits copied vs referenced bytes).
//!
//! [`Document`] survives as a construction-time record: loaders and
//! generators build documents one at a time and [`Corpus::new`] /
//! [`Corpus::push_doc`] flatten them into the arena.

/// One document at construction time: token ids (with repetition, order
/// irrelevant to the model) plus the supervised response y_d (EPS,
/// sentiment, ...). Storage inside [`Corpus`] is the flat arena; this type
/// never appears on the hot path.
#[derive(Clone, Debug, PartialEq)]
pub struct Document {
    pub tokens: Vec<u32>,
    pub response: f64,
}

impl Document {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// A corpus: CSR token arena + responses + the vocabulary size the token
/// ids are indexed against.
#[derive(Clone, Debug, PartialEq)]
pub struct Corpus {
    /// Every document's tokens, concatenated (the arena).
    pub tokens: Vec<u32>,
    /// Document d occupies `tokens[doc_offsets[d]..doc_offsets[d + 1]]`;
    /// length is `num_docs() + 1`, first entry 0, non-decreasing.
    pub doc_offsets: Vec<u32>,
    /// Per-document responses, parallel to documents.
    pub responses: Vec<f64>,
    pub vocab_size: usize,
}

impl Default for Corpus {
    fn default() -> Self {
        Corpus { tokens: Vec::new(), doc_offsets: vec![0], responses: Vec::new(), vocab_size: 0 }
    }
}

impl Corpus {
    /// Flatten construction-time documents into the arena.
    pub fn new(docs: Vec<Document>, vocab_size: usize) -> Self {
        let total: usize = docs.iter().map(|d| d.tokens.len()).sum();
        let mut c = Corpus::with_capacity(docs.len(), total, vocab_size);
        for d in &docs {
            c.push_doc(&d.tokens, d.response);
        }
        c
    }

    /// Empty corpus with preallocated arena capacity.
    pub fn with_capacity(docs: usize, tokens: usize, vocab_size: usize) -> Self {
        let mut doc_offsets = Vec::with_capacity(docs + 1);
        doc_offsets.push(0);
        Corpus {
            tokens: Vec::with_capacity(tokens),
            doc_offsets,
            responses: Vec::with_capacity(docs),
            vocab_size,
        }
    }

    /// Construct directly from arena parts, checking the CSR invariants.
    pub fn from_parts(
        tokens: Vec<u32>,
        doc_offsets: Vec<u32>,
        responses: Vec<f64>,
        vocab_size: usize,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            !doc_offsets.is_empty() && doc_offsets[0] == 0,
            "doc_offsets must start with 0"
        );
        anyhow::ensure!(
            doc_offsets.len() == responses.len() + 1,
            "doc_offsets length {} != responses length {} + 1",
            doc_offsets.len(),
            responses.len()
        );
        anyhow::ensure!(
            *doc_offsets.last().unwrap() as usize == tokens.len(),
            "last offset {} != token count {}",
            doc_offsets.last().unwrap(),
            tokens.len()
        );
        anyhow::ensure!(
            doc_offsets.windows(2).all(|w| w[0] <= w[1]),
            "doc_offsets must be non-decreasing"
        );
        Ok(Corpus { tokens, doc_offsets, responses, vocab_size })
    }

    /// Append one document to the arena. Panics if the arena would exceed
    /// `u32::MAX` tokens (an invariant assert for in-memory construction;
    /// I/O paths use [`Corpus::try_push_doc`] and surface an `Err` instead).
    pub fn push_doc(&mut self, tokens: &[u32], response: f64) {
        self.try_push_doc(tokens, response)
            .expect("token arena exceeds u32::MAX tokens; doc_offsets would wrap");
    }

    /// Fallible [`Corpus::push_doc`]: errors (leaving the corpus unchanged)
    /// instead of panicking when the arena would outgrow its u32 offsets.
    pub fn try_push_doc(&mut self, tokens: &[u32], response: f64) -> anyhow::Result<()> {
        debug_assert!(tokens.iter().all(|&w| (w as usize) < self.vocab_size));
        let end = self.tokens.len() + tokens.len();
        anyhow::ensure!(
            u32::try_from(end).is_ok(),
            "token arena would grow to {end} tokens; the u32 doc_offsets cap is {}",
            u32::MAX
        );
        self.tokens.extend_from_slice(tokens);
        self.doc_offsets.push(end as u32);
        self.responses.push(response);
        Ok(())
    }

    pub fn num_docs(&self) -> usize {
        self.doc_offsets.len().saturating_sub(1)
    }

    pub fn num_tokens(&self) -> usize {
        self.tokens.len()
    }

    /// Document d's tokens: one contiguous arena slice.
    #[inline]
    pub fn doc_tokens(&self, d: usize) -> &[u32] {
        &self.tokens[self.doc_offsets[d] as usize..self.doc_offsets[d + 1] as usize]
    }

    #[inline]
    pub fn doc_len(&self, d: usize) -> usize {
        (self.doc_offsets[d + 1] - self.doc_offsets[d]) as usize
    }

    #[inline]
    pub fn response(&self, d: usize) -> f64 {
        self.responses[d]
    }

    pub fn responses(&self) -> Vec<f64> {
        self.responses.clone()
    }

    /// Zero-copy view of the whole corpus in document order.
    pub fn view(&self) -> CorpusView<'_> {
        CorpusView {
            tokens: &self.tokens,
            doc_offsets: &self.doc_offsets,
            resp: &self.responses,
            vocab: self.vocab_size,
            ids: None,
        }
    }

    /// Zero-copy view of the documents named by `ids` (a shard): token and
    /// response data stay in this corpus's arena, only the index list is
    /// held by the view.
    pub fn view_of<'a>(&'a self, ids: &'a [usize]) -> CorpusView<'a> {
        CorpusView {
            tokens: &self.tokens,
            doc_offsets: &self.doc_offsets,
            resp: &self.responses,
            vocab: self.vocab_size,
            ids: Some(ids),
        }
    }

    /// Materialized sub-corpus by document indices (copies into a fresh
    /// arena). The parallel path uses [`Corpus::view_of`] instead; this
    /// remains for train/test splitting and for owners that must outlive
    /// the source corpus.
    pub fn select(&self, idx: &[usize]) -> Corpus {
        self.view_of(idx).to_corpus()
    }

    /// Structural sanity check (token ids within vocab, no empty docs).
    pub fn validate(&self) -> anyhow::Result<()> {
        self.view().validate()
    }
}

/// Borrowed window into a token arena: either the full arena or a shard's
/// document subset. `Copy` — passing one across the worker fan-out costs a
/// few pointers, never a token copy.
///
/// Since the out-of-core refactor the view borrows the three CSR slices
/// directly rather than a `&Corpus`, so the *same* type (and every consumer
/// downstream of it — trainer, predictor, workers) runs equally over a
/// heap-owned [`Corpus`] and over an mmapped `.arena` file
/// ([`crate::data::arena_file::ArenaMap`]).
#[derive(Clone, Copy, Debug)]
pub struct CorpusView<'a> {
    /// Every document's tokens, concatenated.
    tokens: &'a [u32],
    /// CSR prefix sums: arena doc d is `tokens[off[d]..off[d+1]]`.
    doc_offsets: &'a [u32],
    /// Per-document responses, parallel to arena documents.
    resp: &'a [f64],
    vocab: usize,
    /// `None` = all documents in arena order; `Some` = shard doc indices.
    ids: Option<&'a [usize]>,
}

impl<'a> From<&'a Corpus> for CorpusView<'a> {
    fn from(c: &'a Corpus) -> Self {
        c.view()
    }
}

impl<'a> CorpusView<'a> {
    /// Build a view straight from borrowed CSR slices, checking the same
    /// structural invariants as [`Corpus::from_parts`]. This is how an
    /// mmapped arena hands out views without owning `Vec`s.
    pub fn from_parts(
        tokens: &'a [u32],
        doc_offsets: &'a [u32],
        responses: &'a [f64],
        vocab: usize,
        ids: Option<&'a [usize]>,
    ) -> anyhow::Result<CorpusView<'a>> {
        anyhow::ensure!(
            !doc_offsets.is_empty() && doc_offsets[0] == 0,
            "doc_offsets must start with 0"
        );
        anyhow::ensure!(
            doc_offsets.len() == responses.len() + 1,
            "doc_offsets length {} != responses length {} + 1",
            doc_offsets.len(),
            responses.len()
        );
        anyhow::ensure!(
            *doc_offsets.last().unwrap() as usize == tokens.len(),
            "last offset {} != token count {}",
            doc_offsets.last().unwrap(),
            tokens.len()
        );
        anyhow::ensure!(
            doc_offsets.windows(2).all(|w| w[0] <= w[1]),
            "doc_offsets must be non-decreasing"
        );
        if let Some(ids) = ids {
            let n = doc_offsets.len() - 1;
            if let Some(&bad) = ids.iter().find(|&&d| d >= n) {
                anyhow::bail!("view references document {bad} >= corpus size {n}");
            }
        }
        Ok(CorpusView { tokens, doc_offsets, resp: responses, vocab, ids })
    }

    /// Documents in the *underlying arena* (not the view's subset).
    #[inline]
    fn arena_num_docs(&self) -> usize {
        self.doc_offsets.len() - 1
    }

    /// Arena document d's tokens.
    #[inline]
    fn arena_doc_tokens(&self, d: usize) -> &'a [u32] {
        &self.tokens[self.doc_offsets[d] as usize..self.doc_offsets[d + 1] as usize]
    }

    #[inline]
    fn arena_doc_len(&self, d: usize) -> usize {
        (self.doc_offsets[d + 1] - self.doc_offsets[d]) as usize
    }

    pub fn num_docs(&self) -> usize {
        match self.ids {
            Some(ids) => ids.len(),
            None => self.arena_num_docs(),
        }
    }

    pub fn num_tokens(&self) -> usize {
        match self.ids {
            Some(ids) => ids.iter().map(|&d| self.arena_doc_len(d)).sum(),
            None => self.tokens.len(),
        }
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab
    }

    /// True when this view covers the whole corpus (no index indirection —
    /// the zero-copy *and* zero-index case the ledger prices at 0).
    pub fn is_full(&self) -> bool {
        self.ids.is_none()
    }

    /// Arena document index of the view's i-th document.
    #[inline]
    pub fn doc_id(&self, i: usize) -> usize {
        match self.ids {
            Some(ids) => ids[i],
            None => i,
        }
    }

    /// The i-th document's tokens, borrowed straight from the arena.
    #[inline]
    pub fn doc_tokens(&self, i: usize) -> &'a [u32] {
        self.arena_doc_tokens(self.doc_id(i))
    }

    #[inline]
    pub fn doc_len(&self, i: usize) -> usize {
        self.arena_doc_len(self.doc_id(i))
    }

    #[inline]
    pub fn response(&self, i: usize) -> f64 {
        self.resp[self.doc_id(i)]
    }

    /// Materialize the responses in view order (labels are the one thing a
    /// worker genuinely copies; 8 bytes per document).
    pub fn responses(&self) -> Vec<f64> {
        (0..self.num_docs()).map(|i| self.response(i)).collect()
    }

    /// Iterate `(tokens, response)` in view order.
    pub fn iter_docs(self) -> impl Iterator<Item = (&'a [u32], f64)> + 'a {
        (0..self.num_docs()).map(move |i| (self.doc_tokens(i), self.response(i)))
    }

    /// Local CSR offsets of this view's documents (prefix sums of view doc
    /// lengths; length `num_docs() + 1`). Flat per-token state (e.g. the
    /// trainer's z assignments) indexes with these.
    pub fn local_doc_offsets(&self) -> Vec<u32> {
        let d = self.num_docs();
        let mut off = Vec::with_capacity(d + 1);
        off.push(0u32);
        for i in 0..d {
            off.push(off[i] + self.doc_len(i) as u32);
        }
        off
    }

    /// Copy this view's documents into a fresh owned arena.
    pub fn to_corpus(&self) -> Corpus {
        let mut c =
            Corpus::with_capacity(self.num_docs(), self.num_tokens(), self.vocab_size());
        for i in 0..self.num_docs() {
            c.push_doc(self.doc_tokens(i), self.response(i));
        }
        c
    }

    /// Structural sanity check over exactly the viewed documents: no empty
    /// docs, token ids within vocab, finite responses, in-range doc ids.
    pub fn validate(&self) -> anyhow::Result<()> {
        let vocab = self.vocab_size();
        if let Some(ids) = self.ids {
            if let Some(&bad) = ids.iter().find(|&&d| d >= self.arena_num_docs()) {
                anyhow::bail!(
                    "view references document {bad} >= corpus size {}",
                    self.arena_num_docs()
                );
            }
        }
        for i in 0..self.num_docs() {
            let tokens = self.doc_tokens(i);
            if tokens.is_empty() {
                anyhow::bail!("document {i} is empty");
            }
            if let Some(&w) = tokens.iter().find(|&&w| w as usize >= vocab) {
                anyhow::bail!("document {i} has token id {w} >= vocab size {vocab}");
            }
            if !self.response(i).is_finite() {
                anyhow::bail!("document {i} has non-finite response {}", self.response(i));
            }
        }
        Ok(())
    }
}

/// Flat token arena *without* responses: the serve batcher's per-request
/// document assembly. One request's documents land in a single allocation
/// shared (via `Arc`) by every per-document work item.
#[derive(Clone, Debug, PartialEq)]
pub struct TokenArena {
    pub tokens: Vec<u32>,
    /// Document i occupies `tokens[offsets[i]..offsets[i + 1]]`.
    pub offsets: Vec<u32>,
}

impl TokenArena {
    pub fn from_docs(docs: &[Vec<u32>]) -> Self {
        let total: usize = docs.iter().map(|d| d.len()).sum();
        let mut tokens = Vec::with_capacity(total);
        let mut offsets = Vec::with_capacity(docs.len() + 1);
        offsets.push(0u32);
        for d in docs {
            tokens.extend_from_slice(d);
            // Unreachable through serving: requests arrive through the HTTP
            // layer's 64 MiB body cap (`serve::http::MAX_BODY_BYTES`), far
            // below u32::MAX tokens — this is an invariant assert.
            let end = u32::try_from(tokens.len())
                .expect("request arena exceeds u32::MAX tokens; offsets would wrap");
            offsets.push(end);
        }
        TokenArena { tokens, offsets }
    }

    pub fn num_docs(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    #[inline]
    pub fn doc(&self, i: usize) -> &[u32] {
        &self.tokens[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }
}

/// Train/test split of a corpus.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub train: Corpus,
    pub test: Corpus,
}

impl Dataset {
    pub fn vocab_size(&self) -> usize {
        self.train.vocab_size
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini() -> Corpus {
        Corpus::new(
            vec![
                Document { tokens: vec![0, 1, 1, 2], response: 0.5 },
                Document { tokens: vec![2, 2], response: -1.0 },
                Document { tokens: vec![0], response: 2.0 },
            ],
            3,
        )
    }

    #[test]
    fn counts() {
        let c = mini();
        assert_eq!(c.num_docs(), 3);
        assert_eq!(c.num_tokens(), 7);
        assert_eq!(c.responses(), vec![0.5, -1.0, 2.0]);
        assert_eq!(c.doc_offsets, vec![0, 4, 6, 7]);
        assert_eq!(c.doc_tokens(0), &[0, 1, 1, 2]);
        assert_eq!(c.doc_tokens(2), &[0]);
        assert_eq!(c.doc_len(1), 2);
    }

    #[test]
    fn select_preserves_order() {
        let c = mini();
        let s = c.select(&[2, 0]);
        assert_eq!(s.num_docs(), 2);
        assert_eq!(s.response(0), 2.0);
        assert_eq!(s.response(1), 0.5);
        assert_eq!(s.doc_tokens(1), &[0, 1, 1, 2]);
        assert_eq!(s.vocab_size, 3);
    }

    #[test]
    fn from_parts_checks_invariants() {
        let c = Corpus::from_parts(vec![0, 1, 2], vec![0, 2, 3], vec![1.0, 2.0], 3).unwrap();
        assert_eq!(c.num_docs(), 2);
        assert_eq!(c.doc_tokens(0), &[0, 1]);
        assert_eq!(c.doc_tokens(1), &[2]);
        assert_eq!(c.responses, vec![1.0, 2.0]);
        c.validate().unwrap();
    }

    #[test]
    fn from_parts_rejects_bad_offsets() {
        // missing leading zero
        assert!(Corpus::from_parts(vec![0], vec![1, 1], vec![1.0], 3).is_err());
        // last offset disagrees with token count
        assert!(Corpus::from_parts(vec![0, 1], vec![0, 1], vec![1.0], 3).is_err());
        // offsets/responses length mismatch
        assert!(Corpus::from_parts(vec![0, 1], vec![0, 2], vec![1.0, 2.0], 3).is_err());
        // decreasing offsets
        assert!(
            Corpus::from_parts(vec![0, 1], vec![0, 2, 1, 2], vec![1.0, 2.0, 3.0], 3).is_err()
        );
    }

    #[test]
    fn view_from_parts_checks_invariants_and_matches_corpus_view() {
        let c = mini();
        let v = CorpusView::from_parts(&c.tokens, &c.doc_offsets, &c.responses, 3, None)
            .unwrap();
        assert!(v.is_full());
        assert_eq!(v.num_docs(), 3);
        assert_eq!(v.num_tokens(), 7);
        assert_eq!(v.doc_tokens(1), c.doc_tokens(1));
        assert_eq!(v.responses(), c.responses());
        v.validate().unwrap();
        // shard ids work too, and out-of-range ids are rejected up front
        let ids = vec![2usize, 0];
        let s = CorpusView::from_parts(&c.tokens, &c.doc_offsets, &c.responses, 3, Some(&ids))
            .unwrap();
        assert_eq!(s.num_docs(), 2);
        assert_eq!(s.doc_tokens(0), &[0]);
        let bad = vec![9usize];
        assert!(CorpusView::from_parts(
            &c.tokens,
            &c.doc_offsets,
            &c.responses,
            3,
            Some(&bad)
        )
        .is_err());
        // structural CSR failures mirror Corpus::from_parts
        assert!(CorpusView::from_parts(&[0], &[1, 1], &[1.0], 3, None).is_err());
        assert!(CorpusView::from_parts(&[0, 1], &[0, 1], &[1.0], 3, None).is_err());
        assert!(CorpusView::from_parts(&[0, 1], &[0, 2, 1, 2], &[1.0, 2.0, 3.0], 3, None)
            .is_err());
    }

    #[test]
    fn legacy_and_arena_construction_agree() {
        let legacy = mini();
        let arena = Corpus::from_parts(
            vec![0, 1, 1, 2, 2, 2, 0],
            vec![0, 4, 6, 7],
            vec![0.5, -1.0, 2.0],
            3,
        )
        .unwrap();
        assert_eq!(legacy, arena);
    }

    #[test]
    fn validate_catches_problems() {
        let c = mini();
        c.validate().unwrap();

        // empty document via from_parts
        let c = Corpus::from_parts(vec![0, 1], vec![0, 2, 2], vec![0.5, 1.0], 3).unwrap();
        assert!(c.validate().is_err());

        // out-of-range token id
        let mut c = mini();
        c.tokens[0] = 99;
        assert!(c.validate().is_err());

        // non-finite response
        let mut c = mini();
        c.responses[2] = f64::NAN;
        assert!(c.validate().is_err());
    }

    #[test]
    fn full_view_matches_corpus() {
        let c = mini();
        let v = c.view();
        assert!(v.is_full());
        assert_eq!(v.num_docs(), 3);
        assert_eq!(v.num_tokens(), 7);
        assert_eq!(v.vocab_size(), 3);
        assert_eq!(v.doc_tokens(1), c.doc_tokens(1));
        assert_eq!(v.response(2), 2.0);
        assert_eq!(v.responses(), c.responses());
        assert_eq!(v.local_doc_offsets(), c.doc_offsets);
        let collected: Vec<(Vec<u32>, f64)> =
            v.iter_docs().map(|(t, y)| (t.to_vec(), y)).collect();
        assert_eq!(collected.len(), 3);
        assert_eq!(collected[0].0, vec![0, 1, 1, 2]);
        v.validate().unwrap();
    }

    #[test]
    fn indexed_view_is_zero_copy_window() {
        let c = mini();
        let ids = vec![2usize, 0];
        let v = c.view_of(&ids);
        assert!(!v.is_full());
        assert_eq!(v.num_docs(), 2);
        assert_eq!(v.num_tokens(), 5);
        assert_eq!(v.doc_id(0), 2);
        assert_eq!(v.doc_tokens(0), &[0]);
        assert_eq!(v.doc_tokens(1), &[0, 1, 1, 2]);
        assert_eq!(v.responses(), vec![2.0, 0.5]);
        assert_eq!(v.local_doc_offsets(), vec![0, 1, 5]);
        // the view's token slices alias the arena (zero-copy)
        assert!(std::ptr::eq(v.doc_tokens(1).as_ptr(), c.tokens.as_ptr()));
        // materializing reproduces select()
        assert_eq!(v.to_corpus(), c.select(&ids));
    }

    #[test]
    fn view_edge_cases() {
        let c = mini();
        // empty shard
        let empty: Vec<usize> = vec![];
        let v = c.view_of(&empty);
        assert_eq!(v.num_docs(), 0);
        assert_eq!(v.num_tokens(), 0);
        assert_eq!(v.local_doc_offsets(), vec![0]);
        v.validate().unwrap();
        assert_eq!(v.to_corpus().num_docs(), 0);
        // single-doc shard at both arena extremes
        for (&id, len) in [0usize, 2].iter().zip([4usize, 1]) {
            let ids = vec![id];
            let v = c.view_of(&ids);
            assert_eq!(v.num_docs(), 1);
            assert_eq!(v.num_tokens(), len);
            v.validate().unwrap();
        }
        // out-of-range doc id is caught by validate
        let bad = vec![7usize];
        assert!(c.view_of(&bad).validate().is_err());
    }

    #[test]
    fn view_validate_rejects_out_of_range_tokens() {
        let mut c = mini();
        c.tokens[5] = 42; // inside doc 1 (offsets 4..6)
        let ids = vec![1usize];
        assert!(c.view_of(&ids).validate().is_err());
        let ids = vec![0usize, 2];
        c.view_of(&ids).validate().unwrap(); // other docs untouched
    }

    #[test]
    fn token_arena_assembles_requests() {
        let docs = vec![vec![1u32, 2, 2], vec![7], vec![]];
        let a = TokenArena::from_docs(&docs);
        assert_eq!(a.num_docs(), 3);
        assert_eq!(a.doc(0), &[1, 2, 2]);
        assert_eq!(a.doc(1), &[7]);
        assert!(a.doc(2).is_empty());
        assert_eq!(a.offsets, vec![0, 3, 4, 4]);
    }

    #[test]
    fn default_is_valid_empty() {
        let c = Corpus::default();
        assert_eq!(c.num_docs(), 0);
        assert_eq!(c.num_tokens(), 0);
        c.validate().unwrap();
    }
}
