//! Scoped thread-pool substrate (no rayon/tokio offline).
//!
//! The communication-free algorithm needs exactly one primitive: "run M
//! independent closures on M OS threads, give each its own seeded RNG, and
//! join". [`scoped_map`] provides that with panic propagation; workers share
//! **nothing** mutable during execution — matching the paper's zero
//! inter-machine communication during sampling (the comm ledger in
//! `parallel::comm` audits this).

use std::sync::mpsc;
use std::thread;

/// Run `f(i, &items[i])` for every item on its own thread (up to
/// `max_threads` live at once) and collect the results in order.
/// Panics in workers are propagated to the caller.
pub fn scoped_map<T, R, F>(items: &[T], max_threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    assert!(max_threads > 0);
    let n = items.len();
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    if n == 0 {
        return Vec::new();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    thread::scope(|scope| {
        let (tx, rx) = mpsc::channel::<(usize, R)>();
        let fref = &f;
        let nextref = &next;
        let workers = max_threads.min(n);
        for _ in 0..workers {
            let tx = tx.clone();
            scope.spawn(move || loop {
                let i = nextref.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = fref(i, &items[i]);
                // Receiver only drops after scope join; send cannot fail
                // while any result is still expected.
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        for (i, r) in rx {
            out[i] = Some(r);
        }
    });
    out.into_iter().map(|o| o.expect("worker produced no result")).collect()
}

/// Convenience: number of available CPUs (>= 1).
pub fn num_cpus() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn maps_in_order() {
        let items: Vec<usize> = (0..37).collect();
        let out = scoped_map(&items, 4, |i, &x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, (0..37).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn runs_all_items_once() {
        let counter = AtomicUsize::new(0);
        let items = vec![(); 100];
        scoped_map(&items, 8, |_, _| {
            counter.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn single_thread_works() {
        let items = vec![1, 2, 3];
        assert_eq!(scoped_map(&items, 1, |_, &x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u8> = vec![];
        let out: Vec<u8> = scoped_map(&items, 4, |_, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    #[should_panic]
    fn worker_panic_propagates() {
        let items = vec![0, 1, 2];
        scoped_map(&items, 2, |_, &x| {
            if x == 1 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn actually_parallel() {
        // With 4 threads, 4 sleeps of 30ms should take well under 120ms.
        let items = vec![(); 4];
        let sw = crate::util::timer::Stopwatch::new();
        scoped_map(&items, 4, |_, _| std::thread::sleep(std::time::Duration::from_millis(30)));
        assert!(sw.elapsed_secs() < 0.1, "took {}", sw.elapsed_secs());
    }
}
