//! Graceful-shutdown signal plumbing (DESIGN.md §Durability).
//!
//! `install_shutdown_handler` points SIGINT and SIGTERM at an async-
//! signal-safe handler that does exactly one thing: set a process-global
//! [`AtomicBool`]. Training loops poll that flag at checkpoint boundaries
//! (`sampler::gibbs_train::CkptHook::stop`) and exit cleanly after writing
//! a final checkpoint, so an operator's `kill` (or an orchestrator's
//! SIGTERM before the SIGKILL grace period expires) never loses more than
//! one checkpoint interval of work — and loses none of the chain's
//! byte-identical resumability.
//!
//! The flag is process-global because signal dispositions are: tests that
//! exercise it must [`reset_shutdown_flag`] around their assertions, and
//! the CLI resets it before starting a run.

use std::sync::atomic::{AtomicBool, Ordering};

static SHUTDOWN: AtomicBool = AtomicBool::new(false);

extern "C" fn on_shutdown_signal(_sig: libc::c_int) {
    // Only async-signal-safe work here: a single atomic store.
    SHUTDOWN.store(true, Ordering::SeqCst);
}

/// Route SIGINT + SIGTERM to the shutdown flag. Idempotent; returns an
/// error if the kernel rejects either registration (it won't, short of a
/// broken shim layout — which is exactly what the error would surface).
pub fn install_shutdown_handler() -> anyhow::Result<()> {
    for sig in [libc::SIGINT, libc::SIGTERM] {
        let act = libc::sigaction {
            sa_sigaction: on_shutdown_signal as usize,
            sa_mask: libc::sigset_t::empty(),
            sa_flags: libc::SA_RESTART,
            sa_restorer: 0,
        };
        let rc = unsafe { libc::sigaction(sig, &act, std::ptr::null_mut()) };
        anyhow::ensure!(rc == 0, "sigaction({sig}) failed: {}", std::io::Error::last_os_error());
    }
    Ok(())
}

/// The flag the handler sets. Training polls this through
/// `CkptHook::stop`.
pub fn shutdown_flag() -> &'static AtomicBool {
    &SHUTDOWN
}

/// Has a shutdown signal arrived since the last reset?
pub fn shutdown_requested() -> bool {
    SHUTDOWN.load(Ordering::SeqCst)
}

/// Clear the flag (start of a run; tests). The flag is process-global, so
/// anything that sets it synthetically must clean up after itself.
pub fn reset_shutdown_flag() {
    SHUTDOWN.store(false, Ordering::SeqCst);
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Installs the real handler, delivers a real SIGTERM via `raise`, and
    /// observes the flag. Safe even under a parallel test runner: `raise`
    /// delivers to the calling thread, the handler only sets the flag, and
    /// the flag is cleared again before the test ends.
    #[test]
    fn sigterm_sets_the_shutdown_flag() {
        install_shutdown_handler().unwrap();
        reset_shutdown_flag();
        assert!(!shutdown_requested());
        unsafe {
            assert_eq!(libc::raise(libc::SIGTERM), 0);
        }
        assert!(shutdown_requested(), "handler must set the flag");
        assert!(shutdown_flag().load(std::sync::atomic::Ordering::SeqCst));
        reset_shutdown_flag();
        assert!(!shutdown_requested());
    }
}
