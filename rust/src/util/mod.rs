//! Foundational substrates: RNG, math, statistics, timing, threading,
//! logging. Everything here is dependency-free (no network at build time, so
//! the usual crates — rand, rayon, criterion — are reimplemented in-repo at
//! the scale this project needs).

pub mod alloc_count;
pub mod logging;
pub mod math;
pub mod pool;
pub mod rng;
pub mod signal;
pub mod stats;
pub mod timer;
