//! Descriptive statistics, histograms and two-sample tests.
//!
//! Used by (a) the evaluation layer (summaries over repeated runs — the
//! paper's figures average 100 runs), (b) the Fig-5 label-distribution
//! reproduction (histogram + normality probe), (c) the Fig-1/2
//! quasi-ergodicity demos (Kolmogorov-Smirnov distance between pooled
//! sub-chain samples and the true posterior), and (d) the alias-MH
//! statistical-equivalence suite (chi-square goodness of fit of the alias
//! kernel's per-token topic marginals against the exact conditional —
//! `tests/alias_equivalence.rs`).

use crate::util::math::{gamma_q, normal_cdf};

/// Streaming summary (Welford) of a scalar series.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Self::new();
        for &x in xs {
            s.push(x);
        }
        s
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.std() / (self.n as f64).sqrt() }
    }

    /// Skewness (biased, moment estimator) — used by the Fig-5 normality probe.
    pub fn skewness_of(xs: &[f64]) -> f64 {
        let s = Summary::from_slice(xs);
        if s.n < 3 || s.std() == 0.0 {
            return 0.0;
        }
        let m = s.mean();
        let sd = (s.m2 / s.n as f64).sqrt();
        xs.iter().map(|&x| ((x - m) / sd).powi(3)).sum::<f64>() / s.n as f64
    }

    /// Excess kurtosis (biased).
    pub fn kurtosis_of(xs: &[f64]) -> f64 {
        let s = Summary::from_slice(xs);
        if s.n < 4 || s.std() == 0.0 {
            return 0.0;
        }
        let m = s.mean();
        let sd = (s.m2 / s.n as f64).sqrt();
        xs.iter().map(|&x| ((x - m) / sd).powi(4)).sum::<f64>() / s.n as f64 - 3.0
    }
}

/// Quantile by linear interpolation on a sorted copy (q in [0,1]).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Fixed-width histogram over [lo, hi] with `bins` buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<usize>,
    pub n: usize,
    pub underflow: usize,
    pub overflow: usize,
}

impl Histogram {
    pub fn build(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Self {
        assert!(bins > 0 && hi > lo);
        let mut h = Histogram { lo, hi, counts: vec![0; bins], n: 0, underflow: 0, overflow: 0 };
        let w = (hi - lo) / bins as f64;
        for &x in xs {
            h.n += 1;
            if x < lo {
                h.underflow += 1;
            } else if x >= hi {
                h.overflow += 1;
            } else {
                let b = ((x - lo) / w) as usize;
                h.counts[b.min(bins - 1)] += 1;
            }
        }
        h
    }

    /// Bin centers.
    pub fn centers(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        (0..self.counts.len()).map(|i| self.lo + (i as f64 + 0.5) * w).collect()
    }

    /// ASCII rendering for experiment reports (one row per bin).
    pub fn render(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let centers = self.centers();
        let mut out = String::new();
        for (c, &n) in centers.iter().zip(&self.counts) {
            let bar = "#".repeat(n * width / max);
            out.push_str(&format!("{c:>9.3} | {bar} {n}\n"));
        }
        out
    }
}

/// Pearson chi-square statistic of observed counts against expected
/// counts. Bins with expected mass below `min_expected` are pooled into
/// their successor (the classic small-expected-count guard); pass 0.0 to
/// disable pooling. Returns `(statistic, effective degrees of freedom)` —
/// dof = pooled bins - 1.
pub fn chi_square_stat(observed: &[f64], expected: &[f64], min_expected: f64) -> (f64, usize) {
    assert_eq!(observed.len(), expected.len());
    assert!(!observed.is_empty());
    let mut pooled: Vec<(f64, f64)> = Vec::new();
    let (mut o_acc, mut e_acc) = (0.0f64, 0.0f64);
    for (&o, &e) in observed.iter().zip(expected) {
        o_acc += o;
        e_acc += e;
        if e_acc >= min_expected {
            pooled.push((o_acc, e_acc));
            o_acc = 0.0;
            e_acc = 0.0;
        }
    }
    if o_acc > 0.0 || e_acc > 0.0 {
        // Trailing underweight remainder: fold into the last emitted bin so
        // the min_expected guard holds for every term of the statistic.
        match pooled.last_mut() {
            Some(last) => {
                last.0 += o_acc;
                last.1 += e_acc;
            }
            None => pooled.push((o_acc, e_acc)),
        }
    }
    let stat = pooled.iter().map(|&(o, e)| (o - e) * (o - e) / e.max(1e-12)).sum();
    let dof = pooled.len().saturating_sub(1).max(1);
    (stat, dof)
}

/// Upper-tail chi-square p-value: P(X² >= stat | dof) = Q(dof/2, stat/2).
pub fn chi_square_pvalue(stat: f64, dof: usize) -> f64 {
    gamma_q(dof as f64 / 2.0, stat / 2.0)
}

/// Two-sample Kolmogorov-Smirnov statistic.
pub fn ks_two_sample(a: &[f64], b: &[f64]) -> f64 {
    assert!(!a.is_empty() && !b.is_empty());
    let mut sa = a.to_vec();
    let mut sb = b.to_vec();
    sa.sort_by(|x, y| x.partial_cmp(y).unwrap());
    sb.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let (mut i, mut j) = (0usize, 0usize);
    let (na, nb) = (sa.len() as f64, sb.len() as f64);
    let mut d: f64 = 0.0;
    while i < sa.len() && j < sb.len() {
        if sa[i] <= sb[j] {
            i += 1;
        } else {
            j += 1;
        }
        d = d.max((i as f64 / na - j as f64 / nb).abs());
    }
    d
}

/// One-sample KS statistic against the N(mu, var) CDF.
pub fn ks_vs_normal(xs: &[f64], mu: f64, var: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let n = v.len() as f64;
    let sd = var.sqrt();
    let mut d: f64 = 0.0;
    for (i, &x) in v.iter().enumerate() {
        let f = normal_cdf((x - mu) / sd);
        d = d.max((f - i as f64 / n).abs());
        d = d.max(((i + 1) as f64 / n - f).abs());
    }
    d
}

/// Asymptotic KS p-value (Kolmogorov distribution tail, 100-term series).
pub fn ks_pvalue(d: f64, n_eff: f64) -> f64 {
    let t = (n_eff.sqrt() + 0.12 + 0.11 / n_eff.sqrt()) * d;
    let mut p = 0.0;
    for k in 1..=100 {
        let k = k as f64;
        p += 2.0 * (-1.0f64).powi(k as i32 + 1) * (-2.0 * k * k * t * t).exp();
    }
    p.clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn summary_known_values() {
        let s = Summary::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn quantiles() {
        let xs = [3.0, 1.0, 2.0, 4.0, 5.0];
        assert_eq!(median(&xs), 3.0);
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert!((quantile(&xs, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts_everything() {
        let xs = [-1.0, 0.1, 0.5, 0.9, 2.0];
        let h = Histogram::build(&xs, 0.0, 1.0, 4);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.counts.iter().sum::<usize>(), 3);
        assert_eq!(h.n, 5);
    }

    #[test]
    fn chi_square_detects_fit_and_misfit() {
        // well-matched multinomial sample: large p-value
        let mut r = Pcg64::seed_from_u64(7);
        let probs = [0.1, 0.25, 0.4, 0.2, 0.05];
        let n = 50_000usize;
        let mut obs = [0.0f64; 5];
        for _ in 0..n {
            let u = r.next_f64();
            let mut acc = 0.0;
            for (i, &p) in probs.iter().enumerate() {
                acc += p;
                if u < acc {
                    obs[i] += 1.0;
                    break;
                }
            }
        }
        let expected: Vec<f64> = probs.iter().map(|&p| p * n as f64).collect();
        let (stat, dof) = chi_square_stat(&obs, &expected, 5.0);
        assert_eq!(dof, 4);
        assert!(chi_square_pvalue(stat, dof) > 1e-3, "stat={stat}");
        // a clearly wrong expectation: tiny p-value
        let wrong: Vec<f64> = probs.iter().rev().map(|&p| p * n as f64).collect();
        let (stat, dof) = chi_square_stat(&obs, &wrong, 5.0);
        assert!(chi_square_pvalue(stat, dof) < 1e-10, "stat={stat}");
    }

    #[test]
    fn chi_square_pools_small_expected_bins() {
        let obs = [100.0, 2.0, 1.0, 3.0, 98.0];
        let exp = [100.0, 2.0, 2.0, 2.0, 98.0];
        // min_expected 5 pools the middle three bins (2+2+2 = 6) into one
        let (_, dof) = chi_square_stat(&obs, &exp, 5.0);
        assert_eq!(dof, 2);
        let (_, dof_unpooled) = chi_square_stat(&obs, &exp, 0.0);
        assert_eq!(dof_unpooled, 4);
        // a trailing underweight remainder folds into the last emitted bin
        // instead of forming its own near-zero-expectation bin
        let (stat, dof) = chi_square_stat(&[100.0, 5.0], &[100.0, 0.01], 5.0);
        assert_eq!(dof, 1);
        assert!(stat < 1.0, "trailing bin must be pooled, got stat {stat}");
        // reference p-values: dof=1 at the 5% critical value 3.841
        assert!((chi_square_pvalue(3.841, 1) - 0.05).abs() < 2e-3);
        assert!((chi_square_pvalue(5.991, 2) - 0.05).abs() < 2e-3);
    }

    #[test]
    fn ks_same_distribution_small() {
        let mut r = Pcg64::seed_from_u64(1);
        let a: Vec<f64> = (0..2000).map(|_| r.next_gaussian()).collect();
        let b: Vec<f64> = (0..2000).map(|_| r.next_gaussian()).collect();
        let d = ks_two_sample(&a, &b);
        assert!(d < 0.06, "d={d}");
        let p = ks_pvalue(d, 1000.0);
        assert!(p > 0.01, "p={p}");
    }

    #[test]
    fn ks_different_distributions_large() {
        let mut r = Pcg64::seed_from_u64(2);
        let a: Vec<f64> = (0..2000).map(|_| r.next_gaussian()).collect();
        let b: Vec<f64> = (0..2000).map(|_| r.next_gaussian() + 1.5).collect();
        let d = ks_two_sample(&a, &b);
        assert!(d > 0.4, "d={d}");
        assert!(ks_pvalue(d, 1000.0) < 1e-6);
    }

    #[test]
    fn ks_vs_normal_detects_fit() {
        let mut r = Pcg64::seed_from_u64(3);
        let a: Vec<f64> = (0..3000).map(|_| 2.0 + 0.5 * r.next_gaussian()).collect();
        assert!(ks_vs_normal(&a, 2.0, 0.25) < 0.03);
        assert!(ks_vs_normal(&a, 0.0, 0.25) > 0.5);
    }

    #[test]
    fn skewness_and_kurtosis_of_normal_near_zero() {
        let mut r = Pcg64::seed_from_u64(4);
        let a: Vec<f64> = (0..50_000).map(|_| r.next_gaussian()).collect();
        assert!(Summary::skewness_of(&a).abs() < 0.05);
        assert!(Summary::kurtosis_of(&a).abs() < 0.1);
    }
}
