//! Counting global allocator for the `bench-alloc` feature.
//!
//! Wraps [`std::alloc::System`] and bumps two process-wide relaxed atomics
//! on every allocation, so `serve-bench` (and the codec pin test in
//! `tests/json_streaming.rs`) can report allocs/request and bytes/request
//! for the streaming JSON hot path. Installed as `#[global_allocator]` in
//! `lib.rs` only when the crate is built with `--features bench-alloc`;
//! release builds carry zero overhead.
//!
//! Counters are process-global, so a meaningful measurement must run on a
//! quiet process: single-threaded, before any server/batcher threads start
//! (serve-bench measures the codec loop before booting the first cell, and
//! the pin test runs under `--test-threads=1`).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

/// The counting allocator; a unit struct so it can be a `static`.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // A grow/shrink is one trip to the allocator; only the growth
        // counts toward the byte tally (shrinks are free real estate).
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size.saturating_sub(layout.size()) as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// Snapshot of `(allocations, bytes)` since process start.
pub fn snapshot() -> (u64, u64) {
    (ALLOCS.load(Ordering::Relaxed), BYTES.load(Ordering::Relaxed))
}

/// `(allocations, bytes)` since `before` (a prior [`snapshot`]).
pub fn delta(before: (u64, u64)) -> (u64, u64) {
    let now = snapshot();
    (now.0.wrapping_sub(before.0), now.1.wrapping_sub(before.1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_observe_heap_traffic() {
        let before = snapshot();
        let v: Vec<u64> = Vec::with_capacity(1024);
        let (allocs, bytes) = delta(before);
        // Only meaningful when the counting allocator is actually
        // installed (feature on); otherwise the statics never move.
        if cfg!(feature = "bench-alloc") {
            assert!(allocs >= 1, "Vec::with_capacity must allocate (saw {allocs})");
            assert!(bytes >= 1024 * 8, "expected >= 8KiB counted, saw {bytes}");
        } else {
            assert_eq!((allocs, bytes), (0, 0));
        }
        drop(v);
    }
}
