//! Minimal stderr logger backing the `log` facade.
//!
//! Level comes from `CFSLDA_LOG` (off|error|warn|info|debug|trace, default
//! info; an unrecognized value falls back to info with a one-time warning).
//! Install once at process start (`main.rs`, example binaries).
//!
//! Every record at `warn` or above is also counted into the global metrics
//! registry ([`crate::obs::LogMetrics`]), so `/metrics` reflects log noise
//! without anything scraping stderr.

use log::{Level, LevelFilter, Metadata, Record};
use std::time::Instant;

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        // Count warn/error records whether or not they pass the stderr
        // filter: running at CFSLDA_LOG=off must not blind the counters.
        match record.level() {
            Level::Error => crate::obs::registry().log.errors.inc(),
            Level::Warn => crate::obs::registry().log.warns.inc(),
            _ => {}
        }
        if self.enabled(record.metadata()) {
            let t = self.start.elapsed().as_secs_f64();
            let lvl = match record.level() {
                Level::Error => "ERROR",
                Level::Warn => "WARN ",
                Level::Info => "INFO ",
                Level::Debug => "DEBUG",
                Level::Trace => "TRACE",
            };
            eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

/// Parse a `CFSLDA_LOG` value. `None` means unrecognized (caller decides
/// the fallback); the empty string counts as unset, not unrecognized.
pub fn parse_filter(s: &str) -> Option<LevelFilter> {
    Some(match s {
        "off" => LevelFilter::Off,
        "error" => LevelFilter::Error,
        "warn" => LevelFilter::Warn,
        "" | "info" => LevelFilter::Info,
        "debug" => LevelFilter::Debug,
        "trace" => LevelFilter::Trace,
        _ => return None,
    })
}

/// Install the logger (idempotent — later calls are no-ops).
pub fn init() {
    let raw = std::env::var("CFSLDA_LOG").unwrap_or_default();
    let (level, unknown) = match parse_filter(&raw) {
        Some(l) => (l, false),
        None => (LevelFilter::Info, true),
    };
    let logger = Box::new(StderrLogger { start: Instant::now() });
    if log::set_boxed_logger(logger).is_ok() {
        log::set_max_level(level);
        if unknown {
            // Once per process by construction: only the installing call
            // reaches this branch.
            log::warn!(
                "unrecognized CFSLDA_LOG value {raw:?}; \
                 expected off|error|warn|info|debug|trace, using info"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use log::LevelFilter;

    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }

    #[test]
    fn parse_filter_accepts_all_levels_and_rejects_garbage() {
        assert_eq!(super::parse_filter("off"), Some(LevelFilter::Off));
        assert_eq!(super::parse_filter("error"), Some(LevelFilter::Error));
        assert_eq!(super::parse_filter("warn"), Some(LevelFilter::Warn));
        assert_eq!(super::parse_filter(""), Some(LevelFilter::Info));
        assert_eq!(super::parse_filter("info"), Some(LevelFilter::Info));
        assert_eq!(super::parse_filter("debug"), Some(LevelFilter::Debug));
        assert_eq!(super::parse_filter("trace"), Some(LevelFilter::Trace));
        assert_eq!(super::parse_filter("verbose"), None);
        assert_eq!(super::parse_filter("WARN"), None, "values are lowercase");
    }

    #[test]
    fn warn_and_error_records_land_in_the_obs_counters() {
        super::init();
        let log_metrics = &crate::obs::registry().log;
        let (w0, e0) = (log_metrics.warns.get(), log_metrics.errors.get());
        log::warn!("counted warn");
        log::error!("counted error");
        log::info!("not counted");
        // Counters are global; other tests may log warnings concurrently,
        // so assert at-least movement.
        assert!(log_metrics.warns.get() >= w0 + 1);
        assert!(log_metrics.errors.get() >= e0 + 1);
    }
}
