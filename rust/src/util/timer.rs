//! Wall-clock timing with named phases.
//!
//! The paper's headline comparison is *computation time* across the four
//! algorithms; every run in the experiment layer reports a [`PhaseTimings`]
//! breakdown (train / predict-test / predict-train / combine) so the
//! fig-6/fig-7 shape — Naive fastest, SimpleAvg fast, WeightedAvg slower
//! than NonParallel — is attributable to the right phase.

use std::time::{Duration, Instant};

/// Seconds of CPU time consumed by the *calling thread*
/// (`CLOCK_THREAD_CPUTIME_ID`). Used to simulate per-worker wall time on
/// machines with fewer cores than workers (DESIGN.md §3): a worker's thread
/// CPU time is exactly its wall time on a dedicated core.
pub fn thread_cpu_secs() -> f64 {
    let mut ts = libc::timespec { tv_sec: 0, tv_nsec: 0 };
    let rc = unsafe { libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts) };
    if rc != 0 {
        return 0.0;
    }
    ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
}

/// Stopwatch over the calling thread's CPU clock.
#[derive(Debug)]
pub struct CpuStopwatch {
    start: f64,
}

impl Default for CpuStopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl CpuStopwatch {
    pub fn new() -> Self {
        CpuStopwatch { start: thread_cpu_secs() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        (thread_cpu_secs() - self.start).max(0.0)
    }
}

/// A simple start/stop stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Accumulated named phase timings.
#[derive(Clone, Debug, Default)]
pub struct PhaseTimings {
    entries: Vec<(String, f64)>,
}

impl PhaseTimings {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `secs` to phase `name` (creates the phase on first use).
    pub fn add(&mut self, name: &str, secs: f64) {
        if let Some(e) = self.entries.iter_mut().find(|(n, _)| n == name) {
            e.1 += secs;
        } else {
            self.entries.push((name.to_string(), secs));
        }
    }

    /// Time a closure under phase `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let sw = Stopwatch::new();
        let out = f();
        self.add(name, sw.elapsed_secs());
        out
    }

    pub fn get(&self, name: &str) -> f64 {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, s)| *s).unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.entries.iter().map(|(_, s)| s).sum()
    }

    pub fn entries(&self) -> &[(String, f64)] {
        &self.entries
    }

    /// Merge another breakdown into this one (summing shared phases).
    pub fn merge(&mut self, other: &PhaseTimings) {
        for (n, s) in &other.entries {
            self.add(n, *s);
        }
    }

    /// `phase=1.234s` space-separated rendering.
    pub fn render(&self) -> String {
        self.entries
            .iter()
            .map(|(n, s)| format!("{n}={s:.3}s"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.elapsed_secs() >= 0.004);
    }

    #[test]
    fn phases_accumulate_and_merge() {
        let mut a = PhaseTimings::new();
        a.add("train", 1.0);
        a.add("train", 0.5);
        a.add("predict", 0.25);
        assert!((a.get("train") - 1.5).abs() < 1e-12);
        assert!((a.total() - 1.75).abs() < 1e-12);

        let mut b = PhaseTimings::new();
        b.add("predict", 0.75);
        b.add("combine", 0.1);
        a.merge(&b);
        assert!((a.get("predict") - 1.0).abs() < 1e-12);
        assert!((a.get("combine") - 0.1).abs() < 1e-12);
        assert_eq!(a.entries().len(), 3);
    }

    #[test]
    fn cpu_stopwatch_tracks_busy_work() {
        let sw = CpuStopwatch::new();
        // burn some CPU
        let mut acc = 0u64;
        for i in 0..3_000_000u64 {
            acc = acc.wrapping_add(i * i);
        }
        std::hint::black_box(acc);
        let busy = sw.elapsed_secs();
        assert!(busy > 0.0, "cpu time should advance under load");

        // sleeping must NOT advance the thread CPU clock (much)
        let sw = CpuStopwatch::new();
        std::thread::sleep(Duration::from_millis(30));
        assert!(sw.elapsed_secs() < 0.02, "sleep consumed {}s cpu", sw.elapsed_secs());
    }

    #[test]
    fn time_closure_returns_value() {
        let mut t = PhaseTimings::new();
        let v = t.time("work", || 42);
        assert_eq!(v, 42);
        assert!(t.get("work") >= 0.0);
    }
}
