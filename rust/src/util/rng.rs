//! Deterministic pseudo-random number generation.
//!
//! PCG-XSL-RR 128/64 ("pcg64") — O'Neill's PCG family — plus SplitMix64 for
//! seeding. Every stochastic component in the system (corpus generation,
//! Gibbs initialization/sampling, partitioning, MH demos) draws from a
//! [`Pcg64`] seeded through an explicit, logged seed path so that every
//! experiment is exactly reproducible; parallel shards derive independent
//! streams via [`Pcg64::split`].

/// SplitMix64: used to expand a u64 seed into PCG state/increment.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSL-RR 128/64 generator. 128-bit LCG state, 64-bit output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128, // odd
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Construct from a full 128-bit state/stream pair.
    pub fn new(init_state: u128, init_seq: u128) -> Self {
        let mut rng = Pcg64 { state: 0, inc: (init_seq << 1) | 1 };
        rng.step();
        rng.state = rng.state.wrapping_add(init_state);
        rng.step();
        rng
    }

    /// Seed from a single u64 (SplitMix64-expanded), the common entry point.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let a = splitmix64(&mut sm) as u128;
        let b = splitmix64(&mut sm) as u128;
        let c = splitmix64(&mut sm) as u128;
        let d = splitmix64(&mut sm) as u128;
        Self::new((a << 64) | b, (c << 64) | d)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    /// Next u64 (XSL-RR output function).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Next u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Unbiased uniform integer in [0, bound) (Lemire rejection).
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box-Muller (polar form avoided: we want a fixed
    /// consumption pattern of exactly two u64 per draw for reproducibility).
    #[inline]
    pub fn next_gaussian(&mut self) -> f64 {
        // u1 in (0,1] to avoid ln(0)
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Gamma(shape, 1) via Marsaglia-Tsang; shape > 0.
    pub fn next_gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^{1/a}
            let u: f64 = self.next_f64().max(1e-300);
            return self.next_gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.next_gaussian();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v3;
            }
            if u.max(1e-300).ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln()) {
                return d * v3;
            }
        }
    }

    /// Dirichlet(alpha * 1_k) sample of dimension k.
    pub fn next_dirichlet_sym(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..k).map(|_| self.next_gamma(alpha)).collect();
        let s: f64 = v.iter().sum();
        let s = if s <= 0.0 { 1.0 } else { s };
        for x in &mut v {
            *x /= s;
        }
        v
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn sample_discrete(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.gen_range(weights.len());
        }
        let mut u = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Expose the raw (state, increment) pair for checkpointing. Paired with
    /// [`Pcg64::from_raw`], this round-trips the generator exactly: the
    /// restored stream continues bit-for-bit where the saved one stopped.
    pub fn to_raw(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a raw (state, increment) pair captured by
    /// [`Pcg64::to_raw`]. Unlike [`Pcg64::new`], no seeding transformation is
    /// applied — the fields are restored verbatim.
    pub fn from_raw(state: u128, inc: u128) -> Pcg64 {
        Pcg64 { state, inc }
    }

    /// Derive an independent child stream (distinct LCG increment), used to
    /// give every parallel shard its own reproducible randomness.
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let a = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let b = self.next_u64();
        let mut sm = a ^ b.rotate_left(17);
        let s0 = splitmix64(&mut sm) as u128;
        let s1 = splitmix64(&mut sm) as u128;
        let s2 = splitmix64(&mut sm) as u128;
        let s3 = splitmix64(&mut sm) as u128;
        Pcg64::new((s0 << 64) | s1, (s2 << 64) | s3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::seed_from_u64(42);
        let mut b = Pcg64::seed_from_u64(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::seed_from_u64(1);
        let mut b = Pcg64::seed_from_u64(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_unbiased_coverage() {
        let mut r = Pcg64::seed_from_u64(3);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[r.gen_range(7)] += 1;
        }
        let expect = n as f64 / 7.0;
        for &c in &counts {
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt(), "counts={counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg64::seed_from_u64(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Pcg64::seed_from_u64(13);
        for &shape in &[0.3, 1.0, 2.5, 10.0] {
            let n = 50_000;
            let mean: f64 = (0..n).map(|_| r.next_gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.1 * shape.max(0.5),
                "shape={shape} mean={mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Pcg64::seed_from_u64(17);
        for _ in 0..100 {
            let v = r.next_dirichlet_sym(0.5, 8);
            let s: f64 = v.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(v.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn sample_discrete_respects_weights() {
        let mut r = Pcg64::seed_from_u64(19);
        let w = [1.0, 0.0, 3.0];
        let mut c = [0usize; 3];
        for _ in 0..40_000 {
            c[r.sample_discrete(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        let ratio = c[2] as f64 / c[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seed_from_u64(23);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn raw_roundtrip_resumes_stream_exactly() {
        let mut a = Pcg64::seed_from_u64(97);
        // advance past the seeding transformation so raw state is "mid-stream"
        for _ in 0..137 {
            a.next_u64();
        }
        let (state, inc) = a.to_raw();
        let mut b = Pcg64::from_raw(state, inc);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // the restored stream survives further structured draws too
        assert_eq!(a.next_gaussian().to_bits(), b.next_gaussian().to_bits());
        assert_eq!(a.gen_range(17), b.gen_range(17));
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Pcg64::seed_from_u64(5);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }
}
