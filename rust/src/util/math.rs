//! Scalar math helpers used across the sampler, evaluation and demos.

use std::f64::consts::PI;

/// log(sum(exp(xs))) with max-subtraction for stability.
pub fn logsumexp(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !m.is_finite() {
        return m;
    }
    let s: f64 = xs.iter().map(|&x| (x - m).exp()).sum();
    m + s.ln()
}

/// Gaussian pdf N(x; mu, var). `var` is the *variance* (paper's rho).
#[inline]
pub fn normal_pdf(x: f64, mu: f64, var: f64) -> f64 {
    let d = x - mu;
    (-d * d / (2.0 * var)).exp() / (2.0 * PI * var).sqrt()
}

/// Gaussian log-pdf.
#[inline]
pub fn normal_logpdf(x: f64, mu: f64, var: f64) -> f64 {
    let d = x - mu;
    -0.5 * (2.0 * PI * var).ln() - d * d / (2.0 * var)
}

/// Standard normal CDF via the Abramowitz-Stegun erf approximation
/// (|error| < 7.5e-8 — sufficient for KS p-values and histogram overlays).
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// erf via A&S 7.1.26.
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let y = t
        * (0.254_829_592
            + t * (-0.284_496_736
                + t * (1.421_413_741 + t * (-1.453_152_027 + t * 1.061_405_429))));
    sign * (1.0 - y * (-x * x).exp())
}

/// Fast exp approximation (Schraudolph-style, refined): exploits the IEEE-754
/// double layout to compute e^x with ~2e-4 relative error over |x| < 700.
/// Used in the Gibbs hot path where the Gaussian response margin needs T
/// exponentials per token; exactness there is irrelevant because the values
/// feed an unnormalized categorical draw. Falls back to the exact exp for
/// |x| outside the safe window.
#[inline]
pub fn fast_exp(x: f64) -> f64 {
    if !(-700.0..=700.0).contains(&x) {
        return x.exp();
    }
    // e^x = 2^(x/ln2); split into integer + fractional parts and use a
    // degree-5 polynomial (minimax over [0,1)) for the fractional power.
    const LOG2E: f64 = std::f64::consts::LOG2_E;
    let y = x * LOG2E;
    let yi = y.floor();
    let yf = y - yi;
    // 2^yf via polynomial (Horner), coefficients for 2^t on [0,1)
    let p = 1.0
        + yf * (0.693_147_180_559_945
            + yf * (0.240_226_506_959_101
                + yf * (0.055_504_108_664_822
                    + yf * (0.009_618_129_107_629
                        + yf * (0.001_333_355_814_642_84 + yf * 0.000_154_035_303_933_816)))));
    // scale by 2^yi through exponent bits
    let bits = ((yi as i64 + 1023) as u64) << 52;
    p * f64::from_bits(bits)
}

/// Digamma function (Bernardo's algorithm) — used by hyperparameter
/// optimization (fixed-point alpha/beta updates).
pub fn digamma(mut x: f64) -> f64 {
    let mut result = 0.0;
    while x < 6.0 {
        result -= 1.0 / x;
        x += 1.0;
    }
    let inv = 1.0 / x;
    let inv2 = inv * inv;
    result + x.ln() - 0.5 * inv
        - inv2 * (1.0 / 12.0 - inv2 * (1.0 / 120.0 - inv2 * (1.0 / 252.0 - inv2 / 240.0)))
}

/// ln Gamma via Lanczos (g=7, n=9).
pub fn ln_gamma(x: f64) -> f64 {
    const G: f64 = 7.0;
    const C: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection
        return (PI / (PI * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = C[0];
    let t = x + G + 0.5;
    for (i, &c) in C.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Regularized upper incomplete gamma Q(a, x) = Γ(a, x)/Γ(a) — the
/// chi-square upper-tail CDF is `Q(k/2, x/2)`. Series expansion below the
/// a+1 knee, Lentz continued fraction above (Numerical Recipes 6.2).
pub fn gamma_q(a: f64, x: f64) -> f64 {
    // NaN shape parameters fall through to NaN here (a.is_nan() => neither
    // branch of the <= saves it), matching the old !(a > 0.0) guard.
    if a.is_nan() || a <= 0.0 || x < 0.0 || !x.is_finite() {
        return f64::NAN;
    }
    if x == 0.0 {
        return 1.0;
    }
    if x < a + 1.0 {
        1.0 - gamma_p_series(a, x)
    } else {
        gamma_q_cf(a, x)
    }
}

/// P(a, x) by series: P = e^{-x} x^a / Γ(a) · Σ x^n / (a(a+1)...(a+n)).
fn gamma_p_series(a: f64, x: f64) -> f64 {
    let mut ap = a;
    let mut sum = 1.0 / a;
    let mut del = sum;
    for _ in 0..500 {
        ap += 1.0;
        del *= x / ap;
        sum += del;
        if del.abs() < sum.abs() * 1e-15 {
            break;
        }
    }
    sum * (-x + a * x.ln() - ln_gamma(a)).exp()
}

/// Q(a, x) by modified Lentz continued fraction.
fn gamma_q_cf(a: f64, x: f64) -> f64 {
    const FPMIN: f64 = 1e-300;
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / FPMIN;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..500 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = b + an / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-15 {
            break;
        }
    }
    ((-x + a * x.ln() - ln_gamma(a)).exp() * h).clamp(0.0, 1.0)
}

/// Dot product (f64 accumulate over f32 slices).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logsumexp_matches_naive() {
        let xs: [f64; 4] = [0.1, -2.0, 3.5, 1.0];
        let naive: f64 = xs.iter().map(|x: &f64| x.exp()).sum::<f64>().ln();
        assert!((logsumexp(&xs) - naive).abs() < 1e-12);
    }

    #[test]
    fn logsumexp_stable_for_large_inputs() {
        let xs = [1000.0, 1000.0];
        assert!((logsumexp(&xs) - (1000.0 + 2f64.ln())).abs() < 1e-9);
        assert_eq!(logsumexp(&[f64::NEG_INFINITY, f64::NEG_INFINITY]), f64::NEG_INFINITY);
    }

    #[test]
    fn normal_pdf_integrates_to_one() {
        let (mu, var) = (0.3, 2.0);
        let h = 0.001;
        let sum: f64 = (-10_000..10_000)
            .map(|i| normal_pdf(i as f64 * h, mu, var) * h)
            .sum();
        assert!((sum - 1.0).abs() < 1e-6);
    }

    #[test]
    fn logpdf_consistent_with_pdf() {
        for &x in &[-2.0, 0.0, 1.5] {
            let (p, lp) = (normal_pdf(x, 0.5, 0.7), normal_logpdf(x, 0.5, 0.7));
            assert!((p.ln() - lp).abs() < 1e-12);
        }
    }

    #[test]
    fn erf_reference_values() {
        // A&S table values
        assert!((erf(0.0)).abs() < 1e-7);
        assert!((erf(1.0) - 0.842_700_79).abs() < 1e-6);
        assert!((erf(-1.0) + 0.842_700_79).abs() < 1e-6);
        assert!((erf(2.0) - 0.995_322_27).abs() < 1e-6);
    }

    #[test]
    fn normal_cdf_symmetry() {
        for &x in &[0.1, 0.7, 1.9] {
            assert!((normal_cdf(x) + normal_cdf(-x) - 1.0).abs() < 1e-7);
        }
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn fast_exp_relative_error() {
        let mut worst = 0.0f64;
        let mut x = -30.0;
        while x < 30.0 {
            let rel = (fast_exp(x) - x.exp()).abs() / x.exp();
            worst = worst.max(rel);
            x += 0.0137;
        }
        assert!(worst < 2e-5, "worst rel err {worst}");
    }

    #[test]
    fn fast_exp_extremes_fall_back() {
        assert_eq!(fast_exp(-800.0), (-800.0f64).exp());
        assert!(fast_exp(-745.0).is_finite());
    }

    #[test]
    fn digamma_recurrence() {
        // psi(x+1) = psi(x) + 1/x
        for &x in &[0.3, 1.0, 2.7, 11.0] {
            assert!((digamma(x + 1.0) - digamma(x) - 1.0 / x).abs() < 1e-9, "x={x}");
        }
        // psi(1) = -gamma
        assert!((digamma(1.0) + 0.577_215_664_901_532_9).abs() < 1e-9);
    }

    #[test]
    fn gamma_q_reference_values() {
        // Q(1, x) = e^{-x} (chi-square with 2 dof)
        for &x in &[0.1, 1.0, 2.5, 10.0] {
            assert!((gamma_q(1.0, x) - (-x).exp()).abs() < 1e-10, "x={x}");
        }
        // Q(1/2, x) = erfc(sqrt(x)) (chi-square with 1 dof)
        for &x in &[0.05, 0.5, 2.0, 5.0] {
            let want = 1.0 - erf(x.sqrt());
            assert!((gamma_q(0.5, x) - want).abs() < 1e-6, "x={x}");
        }
        // boundaries and monotonicity in x
        assert_eq!(gamma_q(3.0, 0.0), 1.0);
        assert!(gamma_q(3.0, 1.0) > gamma_q(3.0, 2.0));
        assert!(gamma_q(3.0, 100.0) < 1e-12);
        assert!(gamma_q(-1.0, 1.0).is_nan());
        assert!(gamma_q(1.0, -1.0).is_nan());
        // both evaluation branches agree near the a+1 knee
        let lo = gamma_q(4.0, 4.999_999);
        let hi = gamma_q(4.0, 5.000_001);
        assert!((lo - hi).abs() < 1e-9);
    }

    #[test]
    fn ln_gamma_factorials() {
        // ln Gamma(n+1) = ln n!
        let mut f = 1.0f64;
        for n in 1..15 {
            f *= n as f64;
            assert!((ln_gamma(n as f64 + 1.0) - f.ln()).abs() < 1e-8, "n={n}");
        }
        // Gamma(1/2) = sqrt(pi)
        assert!((ln_gamma(0.5) - 0.5 * PI.ln()).abs() < 1e-9);
    }
}
