//! Collapsed-Gibbs sufficient statistics: the N_{d,t}, N_{t,w}, N_t count
//! matrices of paper eq. (1).
//!
//! Memory layout is chosen for the sampler's access pattern (DESIGN.md
//! §Perf): the inner loop iterates over all T topics for a fixed word w and
//! a fixed document d, so both `ndt` (doc-major, `[d * T + t]`) and `ntw`
//! (**word-major**, `[w * T + t]`) keep the T-strided slices contiguous —
//! one cache line covers 16 u32 topic counts.
//!
//! The optional [`SparseIndex`] mirrors the non-zero structure of `ndt` and
//! `ntw` as sorted topic-id lists, maintained incrementally by `inc`/`dec`.
//! The sparse Gibbs kernel (DESIGN.md §Perf) iterates these lists instead
//! of full T-length rows; the dense kernel leaves the index disabled and
//! pays nothing beyond one predictable branch per update.

/// Sorted insert of a topic id absent from the list (0 -> 1 transition).
/// Shared with the sparse kernel's per-document prediction scratch list.
#[inline]
pub(crate) fn insert_sorted(v: &mut Vec<u16>, x: u16) {
    if let Err(i) = v.binary_search(&x) {
        v.insert(i, x);
    }
}

/// Sorted removal of a topic id present in the list (1 -> 0 transition).
#[inline]
pub(crate) fn remove_sorted(v: &mut Vec<u16>, x: u16) {
    if let Ok(i) = v.binary_search(&x) {
        v.remove(i);
    }
}

/// Non-zero structure of the count matrices: per-document and per-word
/// topic-id lists, each sorted ascending (the sparse kernel relies on the
/// ordering to reproduce the dense kernel's accumulation order bit-exactly).
#[derive(Clone, Debug, Default)]
pub struct SparseIndex {
    /// Per-document sorted list of topics with N_dt > 0.
    pub doc_nz: Vec<Vec<u16>>,
    /// Per-word sorted list of topics with N_tw > 0.
    pub word_nz: Vec<Vec<u16>>,
}

/// Count matrices for one Gibbs chain over one (sub-)corpus.
#[derive(Clone, Debug)]
pub struct CountMatrices {
    /// Number of topics T.
    pub t: usize,
    /// Vocabulary size W.
    pub w: usize,
    /// Number of documents D.
    pub d: usize,
    /// N_{d,t}: topic counts per document, layout `[d * T + t]`.
    pub ndt: Vec<u32>,
    /// N_d: tokens per document.
    pub nd: Vec<u32>,
    /// N_{t,w}: word-topic counts, **word-major** layout `[w * T + t]`.
    pub ntw: Vec<u32>,
    /// N_t: total tokens per topic.
    pub nt: Vec<u32>,
    /// Optional non-zero index for the sparse kernel (see
    /// [`CountMatrices::enable_sparse_index`]).
    pub nz: Option<SparseIndex>,
    /// Optional per-word update counter for the alias kernel's staleness
    /// policy (see [`CountMatrices::enable_alias_rev`]): `alias_rev[w]` is
    /// bumped on every `inc`/`dec` that touches word `w`, so the kernel can
    /// rebuild word `w`'s alias table lazily once the updates since its
    /// build exceed the staleness budget.
    pub alias_rev: Option<Vec<u32>>,
}

impl CountMatrices {
    pub fn new(d: usize, t: usize, w: usize) -> Self {
        CountMatrices {
            t,
            w,
            d,
            ndt: vec![0; d * t],
            nd: vec![0; d],
            ntw: vec![0; w * t],
            nt: vec![0; t],
            nz: None,
            alias_rev: None,
        }
    }

    /// Start counting per-word updates for the alias kernel's staleness
    /// policy (counters reset to zero; maintained by `inc`/`dec` from here
    /// on). Wrapping arithmetic on the consumer side makes overflow benign —
    /// at worst one early rebuild every 2^32 updates.
    pub fn enable_alias_rev(&mut self) {
        self.alias_rev = Some(vec![0u32; self.w]);
    }

    /// Build (or rebuild) the sparse non-zero index from the current
    /// counts. From here on `inc`/`dec` keep it consistent incrementally.
    pub fn enable_sparse_index(&mut self) {
        assert!(self.t <= u16::MAX as usize + 1, "topic ids must fit u16");
        let mut idx = SparseIndex {
            doc_nz: Vec::with_capacity(self.d),
            word_nz: Vec::with_capacity(self.w),
        };
        for d in 0..self.d {
            let row = &self.ndt[d * self.t..(d + 1) * self.t];
            idx.doc_nz
                .push((0..self.t).filter(|&ti| row[ti] > 0).map(|ti| ti as u16).collect());
        }
        for w in 0..self.w {
            let row = &self.ntw[w * self.t..(w + 1) * self.t];
            idx.word_nz
                .push((0..self.t).filter(|&ti| row[ti] > 0).map(|ti| ti as u16).collect());
        }
        self.nz = Some(idx);
    }

    /// Register token `w` of document `d` as assigned to `topic`.
    #[inline]
    pub fn inc(&mut self, d: usize, w: u32, topic: usize) {
        let c = &mut self.ndt[d * self.t + topic];
        *c += 1;
        let doc_first = *c == 1;
        self.nd[d] += 1;
        let cw = &mut self.ntw[w as usize * self.t + topic];
        *cw += 1;
        let word_first = *cw == 1;
        self.nt[topic] += 1;
        if let Some(nz) = &mut self.nz {
            if doc_first {
                insert_sorted(&mut nz.doc_nz[d], topic as u16);
            }
            if word_first {
                insert_sorted(&mut nz.word_nz[w as usize], topic as u16);
            }
        }
        if let Some(rev) = &mut self.alias_rev {
            rev[w as usize] = rev[w as usize].wrapping_add(1);
        }
    }

    /// Remove the assignment of token `w` of document `d` to `topic`.
    #[inline]
    pub fn dec(&mut self, d: usize, w: u32, topic: usize) {
        debug_assert!(self.ndt[d * self.t + topic] > 0);
        let c = &mut self.ndt[d * self.t + topic];
        *c -= 1;
        let doc_empty = *c == 0;
        self.nd[d] -= 1;
        let cw = &mut self.ntw[w as usize * self.t + topic];
        *cw -= 1;
        let word_empty = *cw == 0;
        self.nt[topic] -= 1;
        if let Some(nz) = &mut self.nz {
            if doc_empty {
                remove_sorted(&mut nz.doc_nz[d], topic as u16);
            }
            if word_empty {
                remove_sorted(&mut nz.word_nz[w as usize], topic as u16);
            }
        }
        if let Some(rev) = &mut self.alias_rev {
            rev[w as usize] = rev[w as usize].wrapping_add(1);
        }
    }

    /// Per-document topic count row.
    #[inline]
    pub fn ndt_row(&self, d: usize) -> &[u32] {
        &self.ndt[d * self.t..(d + 1) * self.t]
    }

    /// Sorted topic ids with `N_dt > 0` for document `d`: a borrow of the
    /// live [`SparseIndex`] when enabled (the sparse/alias training paths
    /// keep it consistent through burn-in *and* supervised sweeps, since
    /// every token move goes through `inc`/`dec`), otherwise computed into
    /// `scratch`. Consumers: the count-sided Gram accumulation
    /// (`regress::ridge::gram_moments_from_counts`) and its MSE twin.
    pub fn doc_nonzeros<'a>(&'a self, d: usize, scratch: &'a mut Vec<u16>) -> &'a [u16] {
        if let Some(nz) = &self.nz {
            &nz.doc_nz[d]
        } else {
            scratch.clear();
            let row = self.ndt_row(d);
            scratch.extend(
                (0..self.t).filter(|&ti| row[ti] > 0).map(|ti| ti as u16),
            );
            scratch
        }
    }

    /// Per-word topic count column (contiguous thanks to word-major layout).
    #[inline]
    pub fn ntw_row(&self, w: u32) -> &[u32] {
        let w = w as usize;
        &self.ntw[w * self.t..(w + 1) * self.t]
    }

    /// Empirical topic distribution zbar_d (paper: mean of topic indicators).
    pub fn zbar_row(&self, d: usize) -> Vec<f32> {
        let n = self.nd[d].max(1) as f32;
        self.ndt_row(d).iter().map(|&c| c as f32 / n).collect()
    }

    /// Dense row-major [D, T] zbar matrix (input to the eta solve / predict
    /// artifacts). Values are written straight into the preallocated output
    /// buffer — no per-document temporary.
    pub fn zbar_matrix(&self) -> Vec<f32> {
        let mut out = Vec::new();
        self.zbar_matrix_into(&mut out);
        out
    }

    /// [`CountMatrices::zbar_matrix`] into a caller-owned buffer, so a
    /// training loop's repeated eta steps reuse one allocation.
    pub fn zbar_matrix_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.resize(self.d * self.t, 0.0);
        for d in 0..self.d {
            let n = self.nd[d].max(1) as f32;
            let row = &self.ndt[d * self.t..(d + 1) * self.t];
            let dst = &mut out[d * self.t..(d + 1) * self.t];
            for (o, &c) in dst.iter_mut().zip(row) {
                *o = c as f32 / n;
            }
        }
    }

    /// Random topic initialization over a corpus view: every token gets a
    /// uniform topic, counts are accumulated, and the flat per-token
    /// assignment vector (view order, delimited by
    /// [`crate::data::corpus::CorpusView::local_doc_offsets`]) is returned.
    /// RNG consumption is exactly one `gen_range` per token in document
    /// order — the sequence every trainer has always used, so arena and
    /// legacy construction stay seed-exact.
    pub fn init_random(
        corpus: crate::data::corpus::CorpusView<'_>,
        t: usize,
        rng: &mut crate::util::rng::Pcg64,
    ) -> (CountMatrices, Vec<u16>) {
        let d = corpus.num_docs();
        let w = corpus.vocab_size();
        let mut counts = CountMatrices::new(d, t, w);
        let mut z: Vec<u16> = Vec::with_capacity(corpus.num_tokens());
        for di in 0..d {
            for &wi in corpus.doc_tokens(di) {
                let topic = rng.gen_range(t);
                counts.inc(di, wi, topic);
                z.push(topic as u16);
            }
        }
        (counts, z)
    }

    /// Rebuild count matrices from checkpointed raw vectors. The optional
    /// structures (`nz`, `alias_rev`) come back `None` — restorers re-enable
    /// them exactly as a fresh training run would, so the rebuilt state is
    /// indistinguishable from one that never crashed. Lengths and the count
    /// invariants are validated so a corrupt-but-checksum-valid snapshot
    /// (or a snapshot forged against the wrong corpus) is rejected with an
    /// `Err` rather than producing a silently-wrong chain.
    pub fn from_parts(
        t: usize,
        w: usize,
        d: usize,
        ndt: Vec<u32>,
        nd: Vec<u32>,
        ntw: Vec<u32>,
        nt: Vec<u32>,
    ) -> anyhow::Result<CountMatrices> {
        anyhow::ensure!(ndt.len() == d * t, "ndt length {} != d*t = {}", ndt.len(), d * t);
        anyhow::ensure!(nd.len() == d, "nd length {} != d = {d}", nd.len());
        anyhow::ensure!(ntw.len() == w * t, "ntw length {} != w*t = {}", ntw.len(), w * t);
        anyhow::ensure!(nt.len() == t, "nt length {} != t = {t}", nt.len());
        let c = CountMatrices { t, w, d, ndt, nd, ntw, nt, nz: None, alias_rev: None };
        c.check_invariants()?;
        Ok(c)
    }

    /// Pool another chain's word-topic statistics into this one — the Naive
    /// Combination step 3 ("treat the combination of sub-sample topics as if
    /// they were directly sampled for the whole training sample"). Document
    /// rows are per-shard and are concatenated by the caller; only the
    /// word-topic mass is summed here.
    pub fn absorb_word_topic(&mut self, other: &CountMatrices) {
        assert_eq!(self.t, other.t, "topic count mismatch");
        assert_eq!(self.w, other.w, "vocab mismatch");
        for (a, b) in self.ntw.iter_mut().zip(&other.ntw) {
            *a += b;
        }
        for (a, b) in self.nt.iter_mut().zip(&other.nt) {
            *a += b;
        }
        // Bulk pooling bypasses inc/dec; drop the index and the alias
        // update counters rather than let them go stale (re-enable after
        // pooling if sparse/alias sampling is needed).
        self.nz = None;
        self.alias_rev = None;
    }

    /// Verify internal consistency: sum_t N_dt == N_d, sum_w N_tw == N_t,
    /// sum_d N_d == sum_t N_t. Used by property tests after random sweeps.
    pub fn check_invariants(&self) -> anyhow::Result<()> {
        for d in 0..self.d {
            let s: u32 = self.ndt_row(d).iter().sum();
            if s != self.nd[d] {
                anyhow::bail!("doc {d}: sum_t N_dt = {s} != N_d = {}", self.nd[d]);
            }
        }
        let mut per_topic = vec![0u64; self.t];
        for w in 0..self.w {
            for t in 0..self.t {
                per_topic[t] += self.ntw[w * self.t + t] as u64;
            }
        }
        for t in 0..self.t {
            if per_topic[t] != self.nt[t] as u64 {
                anyhow::bail!("topic {t}: sum_w N_tw = {} != N_t = {}", per_topic[t], self.nt[t]);
            }
        }
        let total_d: u64 = self.nd.iter().map(|&x| x as u64).sum();
        let total_t: u64 = self.nt.iter().map(|&x| x as u64).sum();
        if total_d != total_t {
            anyhow::bail!("token totals disagree: docs {total_d} vs topics {total_t}");
        }
        if let Some(rev) = &self.alias_rev {
            anyhow::ensure!(rev.len() == self.w, "alias_rev length mismatch");
        }
        if let Some(nz) = &self.nz {
            anyhow::ensure!(nz.doc_nz.len() == self.d, "doc_nz row count mismatch");
            anyhow::ensure!(nz.word_nz.len() == self.w, "word_nz row count mismatch");
            for d in 0..self.d {
                let want: Vec<u16> = (0..self.t)
                    .filter(|&ti| self.ndt[d * self.t + ti] > 0)
                    .map(|ti| ti as u16)
                    .collect();
                if nz.doc_nz[d] != want {
                    anyhow::bail!(
                        "doc {d}: sparse list {:?} != non-zeros of ndt {want:?}",
                        nz.doc_nz[d]
                    );
                }
            }
            for w in 0..self.w {
                let want: Vec<u16> = (0..self.t)
                    .filter(|&ti| self.ntw[w * self.t + ti] > 0)
                    .map(|ti| ti as u16)
                    .collect();
                if nz.word_nz[w] != want {
                    anyhow::bail!(
                        "word {w}: sparse list {:?} != non-zeros of ntw {want:?}",
                        nz.word_nz[w]
                    );
                }
            }
        }
        Ok(())
    }

    pub fn total_tokens(&self) -> u64 {
        self.nt.iter().map(|&x| x as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn inc_dec_roundtrip() {
        let mut c = CountMatrices::new(2, 3, 5);
        c.inc(0, 4, 2);
        c.inc(0, 4, 2);
        c.inc(1, 0, 1);
        c.check_invariants().unwrap();
        assert_eq!(c.ndt_row(0), &[0, 0, 2]);
        assert_eq!(c.ntw_row(4), &[0, 0, 2]);
        assert_eq!(c.nd, vec![2, 1]);
        assert_eq!(c.nt, vec![0, 1, 2]);
        c.dec(0, 4, 2);
        c.check_invariants().unwrap();
        assert_eq!(c.total_tokens(), 2);
    }

    #[test]
    fn zbar_normalizes() {
        let mut c = CountMatrices::new(1, 4, 3);
        c.inc(0, 0, 1);
        c.inc(0, 1, 1);
        c.inc(0, 2, 3);
        let z = c.zbar_row(0);
        assert_eq!(z, vec![0.0, 2.0 / 3.0, 0.0, 1.0 / 3.0]);
        let m = c.zbar_matrix();
        assert_eq!(m.len(), 4);
        assert!((m.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn random_sweep_preserves_invariants() {
        let mut rng = Pcg64::seed_from_u64(1);
        let (d, t, w) = (6, 4, 10);
        let mut c = CountMatrices::new(d, t, w);
        // assign random tokens
        let mut assignments = Vec::new();
        for doc in 0..d {
            for _ in 0..20 {
                let word = rng.gen_range(w) as u32;
                let topic = rng.gen_range(t);
                c.inc(doc, word, topic);
                assignments.push((doc, word, topic));
            }
        }
        c.check_invariants().unwrap();
        // random reassignments (the Gibbs inner operation)
        for _ in 0..500 {
            let i = rng.gen_range(assignments.len());
            let (doc, word, old) = assignments[i];
            c.dec(doc, word, old);
            let new = rng.gen_range(t);
            c.inc(doc, word, new);
            assignments[i] = (doc, word, new);
        }
        c.check_invariants().unwrap();
        assert_eq!(c.total_tokens(), (d * 20) as u64);
    }

    #[test]
    fn absorb_pools_word_topic_mass() {
        let mut a = CountMatrices::new(1, 2, 3);
        a.inc(0, 0, 0);
        let mut b = CountMatrices::new(2, 2, 3);
        b.inc(0, 0, 1);
        b.inc(1, 2, 1);
        a.absorb_word_topic(&b);
        assert_eq!(a.ntw_row(0), &[1, 1]);
        assert_eq!(a.nt, vec![1, 2]);
        // doc-side counts of `a` untouched
        assert_eq!(a.nd, vec![1]);
    }

    #[test]
    #[should_panic]
    fn absorb_rejects_mismatched_shapes() {
        let mut a = CountMatrices::new(1, 2, 3);
        let b = CountMatrices::new(1, 3, 3);
        a.absorb_word_topic(&b);
    }

    #[test]
    fn sparse_index_tracks_transitions() {
        let mut c = CountMatrices::new(2, 4, 5);
        c.inc(0, 1, 3); // pre-index counts
        c.enable_sparse_index();
        let nz = c.nz.as_ref().unwrap();
        assert_eq!(nz.doc_nz[0], vec![3]);
        assert_eq!(nz.word_nz[1], vec![3]);

        c.inc(0, 1, 3); // 1 -> 2: no membership change
        c.inc(0, 2, 0); // new topic for doc 0, new word 2
        c.inc(1, 1, 3); // doc 1 gains topic 3; word 1 already has it
        let nz = c.nz.as_ref().unwrap();
        assert_eq!(nz.doc_nz[0], vec![0, 3]);
        assert_eq!(nz.doc_nz[1], vec![3]);
        assert_eq!(nz.word_nz[1], vec![3]);
        assert_eq!(nz.word_nz[2], vec![0]);
        c.check_invariants().unwrap();

        c.dec(0, 1, 3); // 2 -> 1: still present
        assert_eq!(c.nz.as_ref().unwrap().doc_nz[0], vec![0, 3]);
        c.dec(0, 1, 3); // 1 -> 0: doc 0 loses topic 3; word 1 keeps it (doc 1)
        let nz = c.nz.as_ref().unwrap();
        assert_eq!(nz.doc_nz[0], vec![0]);
        assert_eq!(nz.word_nz[1], vec![3]);
        c.check_invariants().unwrap();
    }

    #[test]
    fn sparse_index_survives_random_churn() {
        let mut rng = Pcg64::seed_from_u64(9);
        let (d, t, w) = (5, 6, 12);
        let mut c = CountMatrices::new(d, t, w);
        let mut assignments = Vec::new();
        for doc in 0..d {
            for _ in 0..15 {
                let word = rng.gen_range(w) as u32;
                let topic = rng.gen_range(t);
                c.inc(doc, word, topic);
                assignments.push((doc, word, topic));
            }
        }
        c.enable_sparse_index();
        for _ in 0..1000 {
            let i = rng.gen_range(assignments.len());
            let (doc, word, old) = assignments[i];
            c.dec(doc, word, old);
            let new = rng.gen_range(t);
            c.inc(doc, word, new);
            assignments[i] = (doc, word, new);
        }
        c.check_invariants().unwrap();
    }

    #[test]
    fn absorb_drops_stale_sparse_index() {
        let mut a = CountMatrices::new(1, 2, 3);
        a.inc(0, 0, 0);
        a.enable_sparse_index();
        let mut b = CountMatrices::new(1, 2, 3);
        b.inc(0, 1, 1);
        a.absorb_word_topic(&b);
        assert!(a.nz.is_none());
        a.check_invariants().unwrap_err(); // doc-side counts untouched by design
    }

    #[test]
    fn alias_rev_counts_per_word_updates() {
        let mut c = CountMatrices::new(2, 3, 4);
        c.inc(0, 1, 0); // pre-hook updates are not counted
        c.enable_alias_rev();
        assert!(c.alias_rev.as_ref().unwrap().iter().all(|&x| x == 0));
        c.inc(0, 1, 2);
        c.inc(1, 1, 0);
        c.inc(0, 3, 1);
        c.dec(0, 1, 2); // dec counts too: the table's weights changed
        let rev = c.alias_rev.as_ref().unwrap();
        assert_eq!(rev[1], 3);
        assert_eq!(rev[3], 1);
        assert_eq!(rev[0], 0);
        c.check_invariants().unwrap();
        // bulk pooling drops the counters like it drops the sparse index
        let other = CountMatrices::new(1, 3, 4);
        c.absorb_word_topic(&other);
        assert!(c.alias_rev.is_none());
    }

    #[test]
    fn doc_nonzeros_agrees_with_and_without_index() {
        let mut rng = Pcg64::seed_from_u64(8);
        let (d, t, w) = (4, 6, 9);
        let mut c = CountMatrices::new(d, t, w);
        for doc in 0..d {
            for _ in 0..12 {
                c.inc(doc, rng.gen_range(w) as u32, rng.gen_range(t));
            }
        }
        let mut scratch = Vec::new();
        let plain: Vec<Vec<u16>> =
            (0..d).map(|doc| c.doc_nonzeros(doc, &mut scratch).to_vec()).collect();
        c.enable_sparse_index();
        for doc in 0..d {
            assert_eq!(c.doc_nonzeros(doc, &mut scratch), plain[doc].as_slice());
            let want: Vec<u16> = (0..t)
                .filter(|&ti| c.ndt[doc * t + ti] > 0)
                .map(|ti| ti as u16)
                .collect();
            assert_eq!(plain[doc], want);
        }
        // empty document: empty list either way
        let c2 = CountMatrices::new(1, 3, 2);
        assert!(c2.doc_nonzeros(0, &mut scratch).is_empty());
    }

    #[test]
    fn from_parts_roundtrips_and_validates() {
        let mut rng = Pcg64::seed_from_u64(21);
        let (d, t, w) = (4, 5, 8);
        let mut c = CountMatrices::new(d, t, w);
        for doc in 0..d {
            for _ in 0..12 {
                c.inc(doc, rng.gen_range(w) as u32, rng.gen_range(t));
            }
        }
        let r = CountMatrices::from_parts(
            t,
            w,
            d,
            c.ndt.clone(),
            c.nd.clone(),
            c.ntw.clone(),
            c.nt.clone(),
        )
        .unwrap();
        assert_eq!(r.ndt, c.ndt);
        assert_eq!(r.ntw, c.ntw);
        assert!(r.nz.is_none() && r.alias_rev.is_none());

        // wrong lengths rejected
        let bad_len = CountMatrices::from_parts(
            t,
            w,
            d,
            vec![0; 3],
            c.nd.clone(),
            c.ntw.clone(),
            c.nt.clone(),
        );
        assert!(bad_len.is_err());
        // invariant-breaking payload rejected (nd disagrees with ndt)
        let mut bad_nd = c.nd.clone();
        bad_nd[0] += 1;
        let bad_sum =
            CountMatrices::from_parts(t, w, d, c.ndt.clone(), bad_nd, c.ntw.clone(), c.nt.clone());
        assert!(bad_sum.is_err());
    }

    #[test]
    fn zbar_matrix_matches_rows() {
        let mut c = CountMatrices::new(3, 4, 5);
        let mut rng = Pcg64::seed_from_u64(4);
        for doc in 0..3 {
            for _ in 0..10 {
                c.inc(doc, rng.gen_range(5) as u32, rng.gen_range(4));
            }
        }
        let m = c.zbar_matrix();
        for doc in 0..3 {
            assert_eq!(&m[doc * 4..(doc + 1) * 4], c.zbar_row(doc).as_slice());
        }
    }
}
