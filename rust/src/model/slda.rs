//! The trained sLDA model: everything needed to predict unseen documents.
//!
//! Produced by `sampler::gibbs_train`, consumed by `sampler::gibbs_predict`.
//! phi-hat follows paper eq. (3):
//!   phi_{t,w} = (N_{t,w} + beta) / (N_{t,.} + W beta).

use super::counts::CountMatrices;

/// A fitted sLDA model (one shard's local model, or the full-data model).
#[derive(Clone, Debug)]
pub struct SldaModel {
    /// Number of topics T.
    pub t: usize,
    /// Vocabulary size W.
    pub w: usize,
    /// Regression coefficients eta-hat, length T.
    pub eta: Vec<f64>,
    /// Smoothed topic-word distributions, **word-major** `[w * T + t]`
    /// (same access pattern as the sampler: all topics for one word).
    pub phi: Vec<f32>,
    /// Response variance rho (fixed or learned).
    pub rho: f64,
    /// Dirichlet hyperparameter alpha (needed by the prediction sampler).
    pub alpha: f64,
    /// Training-set MSE of the final eta fit (Weighted Average weights).
    pub train_mse: f64,
    /// Training-set accuracy at the 0.5 threshold (binary responses).
    pub train_acc: f64,
}

impl SldaModel {
    /// Estimate phi-hat from final counts (paper eq. 3).
    pub fn phi_from_counts(counts: &CountMatrices, beta: f64) -> Vec<f32> {
        let (t, w) = (counts.t, counts.w);
        let mut phi = vec![0.0f32; w * t];
        let denom: Vec<f64> =
            counts.nt.iter().map(|&n| n as f64 + w as f64 * beta).collect();
        for wi in 0..w {
            let row = counts.ntw_row(wi as u32);
            for ti in 0..t {
                phi[wi * t + ti] = ((row[ti] as f64 + beta) / denom[ti]) as f32;
            }
        }
        phi
    }

    /// All topics' probability of word `w` (contiguous slice).
    #[inline]
    pub fn phi_row(&self, w: u32) -> &[f32] {
        let w = w as usize;
        &self.phi[w * self.t..(w + 1) * self.t]
    }

    /// phi as topic-major rows (for diagnostics: Hungarian alignment,
    /// top-words rendering). Row `t` has length W and sums to ~1.
    pub fn phi_topic_rows(&self) -> Vec<Vec<f64>> {
        (0..self.t)
            .map(|ti| (0..self.w).map(|wi| self.phi[wi * self.t + ti] as f64).collect())
            .collect()
    }

    /// Top-k most probable words of a topic (ids).
    pub fn top_words(&self, topic: usize, k: usize) -> Vec<u32> {
        let mut idx: Vec<u32> = (0..self.w as u32).collect();
        idx.sort_by(|&a, &b| {
            self.phi[b as usize * self.t + topic]
                .partial_cmp(&self.phi[a as usize * self.t + topic])
                .unwrap()
        });
        idx.truncate(k);
        idx
    }

    /// Point prediction yhat = eta . zbar (paper eq. 5).
    pub fn predict_zbar(&self, zbar: &[f32]) -> f64 {
        debug_assert_eq!(zbar.len(), self.t);
        zbar.iter().zip(&self.eta).map(|(&z, &e)| z as f64 * e).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counts_fixture() -> CountMatrices {
        let mut c = CountMatrices::new(2, 2, 3);
        // topic 0 heavy on word 0; topic 1 heavy on word 2
        for _ in 0..8 {
            c.inc(0, 0, 0);
        }
        for _ in 0..2 {
            c.inc(0, 1, 0);
        }
        for _ in 0..9 {
            c.inc(1, 2, 1);
        }
        c.inc(1, 1, 1);
        c
    }

    #[test]
    fn phi_rows_sum_to_one() {
        let c = counts_fixture();
        let phi = SldaModel::phi_from_counts(&c, 0.1);
        let t = c.t;
        for ti in 0..t {
            let s: f64 = (0..c.w).map(|wi| phi[wi * t + ti] as f64).sum();
            assert!((s - 1.0).abs() < 1e-5, "topic {ti} sums to {s}");
        }
    }

    #[test]
    fn phi_matches_eq3() {
        let c = counts_fixture();
        let beta = 0.5;
        let phi = SldaModel::phi_from_counts(&c, beta);
        // N_{0,0} = 8, N_0 = 10, W = 3 -> (8 + .5) / (10 + 1.5)
        assert!((phi[0] as f64 - 8.5 / 11.5).abs() < 1e-6);
        // N_{1,2} = 9, N_1 = 10 -> (9 + .5)/11.5
        assert!((phi[2 * 2 + 1] as f64 - 9.5 / 11.5).abs() < 1e-6);
    }

    fn model_fixture() -> SldaModel {
        let c = counts_fixture();
        SldaModel {
            t: 2,
            w: 3,
            eta: vec![1.0, -2.0],
            phi: SldaModel::phi_from_counts(&c, 0.1),
            rho: 0.5,
            alpha: 0.3,
            train_mse: 0.1,
            train_acc: 0.9,
        }
    }

    #[test]
    fn predict_is_dot_product() {
        let m = model_fixture();
        let y = m.predict_zbar(&[0.25, 0.75]);
        assert!((y - (0.25 - 1.5)).abs() < 1e-9);
    }

    #[test]
    fn top_words_ranked_by_phi() {
        let m = model_fixture();
        assert_eq!(m.top_words(0, 1), vec![0]); // topic 0 loves word 0
        assert_eq!(m.top_words(1, 1), vec![2]); // topic 1 loves word 2
    }

    #[test]
    fn topic_rows_transpose_consistent() {
        let m = model_fixture();
        let rows = m.phi_topic_rows();
        for ti in 0..m.t {
            for wi in 0..m.w {
                assert!((rows[ti][wi] - m.phi[wi * m.t + ti] as f64).abs() < 1e-9);
            }
        }
    }
}
