//! Plain (unsupervised) LDA via collapsed Gibbs (Griffiths & Steyvers 2004).
//!
//! Used by the quasi-ergodicity diagnostics (Fig 2/3 reproduction): LDA's
//! topic posterior has one mode per topic-label permutation, so independent
//! chains on different shards converge to different permutations — exactly
//! the failure the paper's prediction-space combination sidesteps. The
//! Hungarian probe in `eval::mode_diag` quantifies the misalignment on
//! models trained here.

use super::counts::CountMatrices;
use super::slda::SldaModel;
use crate::data::corpus::CorpusView;
use crate::util::rng::Pcg64;

/// Train plain LDA; returns phi-hat (word-major, like [`SldaModel::phi`])
/// and the final counts. Accepts `&Corpus` or any [`CorpusView`].
pub fn train_lda<'a>(
    corpus: impl Into<CorpusView<'a>>,
    topics: usize,
    alpha: f64,
    beta: f64,
    sweeps: usize,
    rng: &mut Pcg64,
) -> (Vec<f32>, CountMatrices) {
    let corpus: CorpusView<'a> = corpus.into();
    let t = topics;
    let d = corpus.num_docs();
    let wbeta = corpus.vocab_size() as f64 * beta;

    let z_offsets = corpus.local_doc_offsets();
    let (mut counts, mut z) = CountMatrices::init_random(corpus, t, rng);

    let mut probs = vec![0.0f64; t];
    for _ in 0..sweeps {
        for di in 0..d {
            let tokens = corpus.doc_tokens(di);
            let zd = &mut z[z_offsets[di] as usize..z_offsets[di + 1] as usize];
            for (n, &wi) in tokens.iter().enumerate() {
                let old = zd[n] as usize;
                counts.dec(di, wi, old);
                {
                    let ndt = &counts.ndt[di * t..(di + 1) * t];
                    let ntw = &counts.ntw[wi as usize * t..(wi as usize + 1) * t];
                    for ti in 0..t {
                        probs[ti] = (ndt[ti] as f64 + alpha)
                            * (ntw[ti] as f64 + beta)
                            / (counts.nt[ti] as f64 + wbeta);
                    }
                }
                let new = rng.sample_discrete(&probs);
                counts.inc(di, wi, new);
                zd[n] = new as u16;
            }
        }
    }
    (SldaModel::phi_from_counts(&counts, beta), counts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_corpus, SyntheticSpec};

    #[test]
    fn lda_recovers_topic_structure() {
        // Corpus with sharply separated topics: after training, each
        // learned topic should concentrate on a subset of the vocabulary.
        let mut spec = SyntheticSpec::continuous_small();
        spec.topics = 4;
        spec.beta = 0.01; // peaky generating topics
        spec.docs = 150;
        spec.vocab = 200;
        let mut rng = Pcg64::seed_from_u64(1);
        let corpus = generate_corpus(&spec, &mut rng);
        let (phi, counts) = train_lda(&corpus, 4, 0.3, 0.05, 30, &mut rng);
        counts.check_invariants().unwrap();
        // entropy of each learned topic must be far below uniform
        let uniform_entropy = (spec.vocab as f64).ln();
        for ti in 0..4 {
            let mut h = 0.0;
            for wi in 0..spec.vocab {
                let p = phi[wi * 4 + ti] as f64;
                if p > 0.0 {
                    h -= p * p.ln();
                }
            }
            assert!(h < 0.85 * uniform_entropy, "topic {ti} entropy {h} vs uniform {uniform_entropy}");
        }
    }

    #[test]
    fn counts_preserved_and_deterministic() {
        let spec = SyntheticSpec::continuous_small();
        let mut rng = Pcg64::seed_from_u64(2);
        let corpus = generate_corpus(&spec, &mut rng);
        let (phi_a, counts) = train_lda(&corpus, 6, 0.5, 0.1, 5, &mut Pcg64::seed_from_u64(3));
        assert_eq!(counts.total_tokens(), corpus.num_tokens() as u64);
        let (phi_b, _) = train_lda(&corpus, 6, 0.5, 0.1, 5, &mut Pcg64::seed_from_u64(3));
        assert_eq!(phi_a, phi_b);
    }
}
