//! Model layer: sufficient-statistic count matrices for collapsed Gibbs,
//! the trained sLDA model (eta, phi-hat, rho), and plain unsupervised LDA
//! (used by the quasi-ergodicity diagnostics).

pub mod counts;
pub mod lda;
pub mod persist;
pub mod slda;
