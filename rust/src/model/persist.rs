//! Model persistence: a compact binary format for trained [`SldaModel`]s,
//! enabling the production `cfslda train` → `cfslda predict`/`cfslda serve`
//! workflow (train once, serve predictions later without retraining).
//!
//! Two on-disk versions share one loader:
//!
//! * **v2 (current, `CFSLDA2`)** — little-endian:
//!   magic "CFSLDA2\0" | u32 t | u32 w | f64 rho | f64 alpha |
//!   f64 train_mse | f64 train_acc | f64 eta[t] | f32 phi[w*t] |
//!   u32 vocab_len | vocab_len × (u32 byte_len | utf8 bytes) | u64 fnv
//!   `vocab_len` is 0 when no vocabulary was persisted; otherwise it must
//!   equal `w` (term `i` names word id `i`). The vocabulary is what lets
//!   `cfslda serve` answer `POST /predict/text` and `top-words` render
//!   real words instead of word ids.
//! * **v1 (legacy, `CFSLDA1`)** — identical up to `phi`, no vocab section.
//!   Still loaded transparently; [`save_model_v1`] keeps a writer around
//!   for cross-version tests and downgrade tooling.
//!
//! The trailing FNV-1a checksum covers everything after the magic.

use super::slda::SldaModel;
use crate::data::vocab::Vocab;
use anyhow::{bail, Context};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC_V1: &[u8; 8] = b"CFSLDA1\0";
const MAGIC_V2: &[u8; 8] = b"CFSLDA2\0";

/// Hard cap on a single persisted vocabulary term (bytes): anything larger
/// is a corrupted length field, not a phrase.
const MAX_TERM_BYTES: usize = 1 << 16;

/// FNV-1a 64-bit. Shared with the checkpoint format (`crate::ckpt`), which
/// uses the same magic + LE body + trailing-checksum file layout.
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

fn core_body(model: &SldaModel) -> Vec<u8> {
    let mut body: Vec<u8> = Vec::with_capacity(40 + model.eta.len() * 8 + model.phi.len() * 4);
    body.extend_from_slice(&(model.t as u32).to_le_bytes());
    body.extend_from_slice(&(model.w as u32).to_le_bytes());
    body.extend_from_slice(&model.rho.to_le_bytes());
    body.extend_from_slice(&model.alpha.to_le_bytes());
    body.extend_from_slice(&model.train_mse.to_le_bytes());
    body.extend_from_slice(&model.train_acc.to_le_bytes());
    for &e in &model.eta {
        body.extend_from_slice(&e.to_le_bytes());
    }
    for &p in &model.phi {
        body.extend_from_slice(&p.to_le_bytes());
    }
    body
}

fn write_file(path: &Path, magic: &[u8; 8], body: &[u8]) -> anyhow::Result<()> {
    let mut f = BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?,
    );
    f.write_all(magic)?;
    f.write_all(body)?;
    f.write_all(&fnv1a(body).to_le_bytes())?;
    Ok(())
}

/// Serialize a model to `path` (current format, no vocabulary payload).
pub fn save_model(model: &SldaModel, path: &Path) -> anyhow::Result<()> {
    save_model_with_vocab(model, None, path)
}

/// Serialize a model plus an optional vocabulary (current `CFSLDA2` format).
/// When given, the vocabulary must have exactly `model.w` terms.
pub fn save_model_with_vocab(
    model: &SldaModel,
    vocab: Option<&Vocab>,
    path: &Path,
) -> anyhow::Result<()> {
    let mut body = core_body(model);
    match vocab {
        None => body.extend_from_slice(&0u32.to_le_bytes()),
        Some(v) => {
            anyhow::ensure!(
                v.len() == model.w,
                "vocabulary has {} terms but model vocab size is {}",
                v.len(),
                model.w
            );
            body.extend_from_slice(&(v.len() as u32).to_le_bytes());
            for term in v.terms() {
                let bytes = term.as_bytes();
                anyhow::ensure!(bytes.len() <= MAX_TERM_BYTES, "vocabulary term too long");
                body.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
                body.extend_from_slice(bytes);
            }
        }
    }
    write_file(path, MAGIC_V2, &body)
}

/// Legacy `CFSLDA1` writer (no vocabulary section). Kept so cross-version
/// loads stay covered by tests and old consumers can be fed downgrades.
pub fn save_model_v1(model: &SldaModel, path: &Path) -> anyhow::Result<()> {
    write_file(path, MAGIC_V1, &core_body(model))
}

/// Load a model from `path`, verifying structure and checksum. Accepts both
/// format versions; any vocabulary payload is dropped (see
/// [`load_model_full`]).
pub fn load_model(path: &Path) -> anyhow::Result<SldaModel> {
    load_model_full(path).map(|(m, _)| m)
}

/// Load a model and its persisted vocabulary (if any) from `path`.
pub fn load_model_full(path: &Path) -> anyhow::Result<(SldaModel, Option<Vocab>)> {
    let mut f = BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic).context("reading magic")?;
    let version = if &magic == MAGIC_V1 {
        1u32
    } else if &magic == MAGIC_V2 {
        2u32
    } else {
        bail!("{path:?} is not a cfslda model (bad magic)");
    };
    let mut rest = Vec::new();
    f.read_to_end(&mut rest)?;
    if rest.len() < 8 {
        bail!("truncated model file");
    }
    let (body, ck) = rest.split_at(rest.len() - 8);
    let want = u64::from_le_bytes(ck.try_into().unwrap());
    if fnv1a(body) != want {
        bail!("model checksum mismatch — corrupted file");
    }

    // Cursor with offset-bearing errors: a malformed (but checksum-valid,
    // e.g. restamped) file names exactly where the structure broke.
    let mut off = 0usize;
    let mut take = |n: usize| -> anyhow::Result<&[u8]> {
        let avail = body.len() - off;
        if n > avail {
            bail!("truncated model body at offset {off}: need {n} bytes, {avail} available");
        }
        let s = &body[off..off + n];
        off += n;
        Ok(s)
    };
    let t = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
    let w = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
    if t == 0 || w == 0 || t > 1 << 16 || w > 1 << 28 {
        bail!("implausible model dims t={t} w={w}");
    }
    let rho = f64::from_le_bytes(take(8)?.try_into().unwrap());
    let alpha = f64::from_le_bytes(take(8)?.try_into().unwrap());
    let train_mse = f64::from_le_bytes(take(8)?.try_into().unwrap());
    let train_acc = f64::from_le_bytes(take(8)?.try_into().unwrap());
    // The dims are attacker-controlled until proven backed by bytes: check
    // the full eta+phi extent (checked arithmetic — w*t*4 can reach 2^46)
    // BEFORE allocating, so a hostile header can't request a huge buffer.
    let eta_bytes = t * 8; // t <= 2^16
    let phi_bytes = w
        .checked_mul(t)
        .and_then(|wt| wt.checked_mul(4))
        .ok_or_else(|| anyhow::anyhow!("model dims t={t} w={w} overflow"))?;
    let avail = body.len() - off;
    if eta_bytes + phi_bytes > avail {
        bail!(
            "truncated model body at offset {off}: eta+phi for t={t} w={w} need {} bytes, \
             {avail} available",
            eta_bytes + phi_bytes
        );
    }
    let mut eta = Vec::with_capacity(t);
    for _ in 0..t {
        eta.push(f64::from_le_bytes(take(8)?.try_into().unwrap()));
    }
    let mut phi = Vec::with_capacity(w * t);
    for _ in 0..w * t {
        phi.push(f32::from_le_bytes(take(4)?.try_into().unwrap()));
    }
    let vocab = if version >= 2 {
        let vlen = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
        if vlen == 0 {
            None
        } else {
            if vlen != w {
                bail!("vocabulary has {vlen} terms but model vocab size is {w}");
            }
            // Every term carries at least a 4-byte length prefix; reject a
            // vlen the remaining bytes cannot possibly back before
            // reserving term storage for it.
            if vlen * 4 > body.len() - off {
                bail!(
                    "truncated model body at offset {off}: {vlen} vocabulary terms need \
                     at least {} bytes, {} available",
                    vlen * 4,
                    body.len() - off
                );
            }
            let mut terms = Vec::with_capacity(vlen);
            for _ in 0..vlen {
                let blen = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
                if blen > MAX_TERM_BYTES {
                    bail!("implausible vocabulary term length {blen} at offset {off}");
                }
                let s = std::str::from_utf8(take(blen)?)
                    .context("vocabulary term is not valid utf-8")?;
                terms.push(s.to_string());
            }
            Some(Vocab::from_terms(terms)?)
        }
    } else {
        None
    };
    if off != body.len() {
        bail!("trailing bytes in model file");
    }
    Ok((SldaModel { t, w, eta, phi, rho, alpha, train_mse, train_acc }, vocab))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cfslda_model_{}_{name}", std::process::id()));
        p
    }

    fn random_model(t: usize, w: usize, seed: u64) -> SldaModel {
        let mut rng = Pcg64::seed_from_u64(seed);
        SldaModel {
            t,
            w,
            eta: (0..t).map(|_| rng.next_gaussian()).collect(),
            phi: (0..w * t).map(|_| rng.next_f32()).collect(),
            rho: 0.42,
            alpha: 0.3,
            train_mse: 0.1,
            train_acc: 0.9,
        }
    }

    fn vocab_of(w: usize) -> Vocab {
        Vocab::from_terms((0..w).map(|i| format!("term_{i}"))).unwrap()
    }

    #[test]
    fn roundtrip_exact() {
        let m = random_model(8, 100, 1);
        let p = tmp("rt.bin");
        save_model(&m, &p).unwrap();
        let (m2, v2) = load_model_full(&p).unwrap();
        assert_eq!(m.t, m2.t);
        assert_eq!(m.w, m2.w);
        assert_eq!(m.eta, m2.eta);
        assert_eq!(m.phi, m2.phi);
        assert_eq!(m.rho, m2.rho);
        assert_eq!(m.train_acc, m2.train_acc);
        assert!(v2.is_none());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn roundtrip_with_vocab() {
        let m = random_model(4, 37, 5);
        let v = vocab_of(37);
        let p = tmp("rt_vocab.bin");
        save_model_with_vocab(&m, Some(&v), &p).unwrap();
        let (m2, v2) = load_model_full(&p).unwrap();
        let v2 = v2.expect("vocab should roundtrip");
        assert_eq!(m.phi, m2.phi);
        assert_eq!(v2.len(), 37);
        assert_eq!(v2.term(0), Some("term_0"));
        assert_eq!(v2.id("term_36"), Some(36));
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn vocab_size_mismatch_rejected_on_save() {
        let m = random_model(4, 30, 6);
        let v = vocab_of(29);
        let p = tmp("mismatch.bin");
        let err = save_model_with_vocab(&m, Some(&v), &p).unwrap_err().to_string();
        assert!(err.contains("29"), "{err}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn v1_files_still_load() {
        // Cross-version: a legacy CFSLDA1 file loads into the same model,
        // with no vocabulary.
        let m = random_model(6, 50, 7);
        let p = tmp("v1.bin");
        save_model_v1(&m, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[..8], b"CFSLDA1\0");
        let (m2, v2) = load_model_full(&p).unwrap();
        assert_eq!(m.eta, m2.eta);
        assert_eq!(m.phi, m2.phi);
        assert!(v2.is_none());
        // and the plain loader too
        let m3 = load_model(&p).unwrap();
        assert_eq!(m.phi, m3.phi);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn corruption_detected() {
        let m = random_model(4, 30, 2);
        let p = tmp("corrupt.bin");
        save_model(&m, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        let err = load_model(&p).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn corruption_detected_in_vocab_section() {
        let m = random_model(3, 20, 9);
        let p = tmp("corrupt_vocab.bin");
        save_model_with_vocab(&m, Some(&vocab_of(20)), &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let n = bytes.len();
        bytes[n - 12] ^= 0x55; // inside the last vocab term
        std::fs::write(&p, &bytes).unwrap();
        let err = load_model_full(&p).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn truncation_and_bad_magic_detected() {
        let m = random_model(4, 30, 3);
        let p = tmp("trunc.bin");
        save_model(&m, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 20]).unwrap();
        assert!(load_model(&p).is_err());
        // shorter than magic + checksum
        std::fs::write(&p, &bytes[..10]).unwrap();
        assert!(load_model(&p).is_err());
        std::fs::write(&p, b"NOTAMODL").unwrap();
        assert!(load_model(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    /// Restamp a mangled body with a fresh valid checksum so the structural
    /// parser (not the checksum gate) is what gets exercised.
    fn restamp(magic: &[u8], body: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(magic.len() + body.len() + 8);
        out.extend_from_slice(magic);
        out.extend_from_slice(body);
        out.extend_from_slice(&fnv1a(body).to_le_bytes());
        out
    }

    #[test]
    fn hostile_dims_rejected_before_allocation() {
        // A tiny file claiming t=2^16, w=2^28 would ask the old loader for a
        // multi-terabyte phi buffer; the hardened loader must refuse from
        // the byte-availability check alone.
        let mut body = Vec::new();
        body.extend_from_slice(&(1u32 << 16).to_le_bytes()); // t (max allowed)
        body.extend_from_slice(&(1u32 << 28).to_le_bytes()); // w (max allowed)
        body.extend_from_slice(&[0u8; 32]); // rho/alpha/mse/acc
        let p = tmp("hostile.bin");
        std::fs::write(&p, restamp(MAGIC_V2, &body)).unwrap();
        let err = load_model(&p).unwrap_err().to_string();
        assert!(err.contains("truncated model body"), "{err}");
        assert!(err.contains("offset"), "{err}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn hostile_vocab_len_rejected_before_allocation() {
        // Valid model section, then a vlen matching w but with no bytes
        // behind it: must fail on the availability precheck.
        let m = random_model(2, 1000, 12);
        let mut body = core_body(&m);
        body.extend_from_slice(&1000u32.to_le_bytes()); // vlen == w, zero term bytes
        let p = tmp("hostile_vocab.bin");
        std::fs::write(&p, restamp(MAGIC_V2, &body)).unwrap();
        let err = load_model_full(&p).unwrap_err().to_string();
        assert!(err.contains("vocabulary terms need"), "{err}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn mangled_file_corpus_never_panics() {
        // Fault-injection corpus over both format versions: random bit
        // flips, truncations, and restamped truncations/flips. Every
        // mutation must yield Err or a loadable model — never a panic, and
        // never an OOM-scale allocation (the case would time out).
        use crate::testkit::{forall, usize_in};
        let m = random_model(5, 24, 33);
        let v2 = {
            let p = tmp("mangle_src2.bin");
            save_model_with_vocab(&m, Some(&vocab_of(24)), &p).unwrap();
            let b = std::fs::read(&p).unwrap();
            std::fs::remove_file(p).ok();
            b
        };
        let v1 = {
            let p = tmp("mangle_src1.bin");
            save_model_v1(&m, &p).unwrap();
            let b = std::fs::read(&p).unwrap();
            std::fs::remove_file(p).ok();
            b
        };
        forall(
            "persist-mangled-files",
            60,
            |rng| {
                let src = if rng.gen_range(2) == 0 { &v1 } else { &v2 };
                let mode = rng.gen_range(3);
                match mode {
                    0 => {
                        // bit flip (checksum gate catches it)
                        let mut b = src.clone();
                        let i = rng.gen_range(b.len());
                        b[i] ^= 1 << rng.gen_range(8);
                        b
                    }
                    1 => {
                        // raw truncation
                        let n = usize_in(rng, 0, src.len().saturating_sub(1));
                        src[..n].to_vec()
                    }
                    _ => {
                        // truncate the body, restamp a valid checksum: the
                        // structural parser must catch it
                        let body = &src[8..src.len() - 8];
                        let n = usize_in(rng, 0, body.len().saturating_sub(1));
                        restamp(&src[..8], &body[..n])
                    }
                }
            },
            |bytes| {
                let p = tmp("mangle_case.bin");
                std::fs::write(&p, bytes).unwrap();
                // Err is expected; Ok is tolerated for no-op mutations.
                // A panic fails the property with the replayable case seed.
                let _ = load_model_full(&p);
                std::fs::remove_file(p).ok();
            },
        );
    }

    #[test]
    fn truncated_mid_body_with_valid_checksum_detected() {
        // Re-checksum a truncated body: structure check must still catch it.
        let m = random_model(4, 30, 4);
        let p = tmp("restamp.bin");
        save_model(&m, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        let body = &bytes[8..bytes.len() - 8];
        let cut = &body[..body.len() - 13];
        let mut out = Vec::new();
        out.extend_from_slice(&bytes[..8]);
        out.extend_from_slice(cut);
        out.extend_from_slice(&fnv1a(cut).to_le_bytes());
        std::fs::write(&p, &out).unwrap();
        let err = load_model(&p).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        std::fs::remove_file(p).ok();
    }
}
