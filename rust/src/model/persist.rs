//! Model persistence: a compact binary format for trained [`SldaModel`]s,
//! enabling the production `cfslda run --save-model` → `cfslda predict`
//! workflow (train once, serve predictions later without retraining).
//!
//! Format (little-endian):
//!   magic "CFSLDA1\0" | u32 t | u32 w | f64 rho | f64 alpha |
//!   f64 train_mse | f64 train_acc | f64 eta[t] | f32 phi[w*t] | u64 fnv
//! The trailing FNV-1a checksum covers everything after the magic.

use super::slda::SldaModel;
use anyhow::{bail, Context};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"CFSLDA1\0";

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Serialize a model to `path`.
pub fn save_model(model: &SldaModel, path: &Path) -> anyhow::Result<()> {
    let mut body: Vec<u8> = Vec::with_capacity(32 + model.eta.len() * 8 + model.phi.len() * 4);
    body.extend_from_slice(&(model.t as u32).to_le_bytes());
    body.extend_from_slice(&(model.w as u32).to_le_bytes());
    body.extend_from_slice(&model.rho.to_le_bytes());
    body.extend_from_slice(&model.alpha.to_le_bytes());
    body.extend_from_slice(&model.train_mse.to_le_bytes());
    body.extend_from_slice(&model.train_acc.to_le_bytes());
    for &e in &model.eta {
        body.extend_from_slice(&e.to_le_bytes());
    }
    for &p in &model.phi {
        body.extend_from_slice(&p.to_le_bytes());
    }
    let mut f = BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&body)?;
    f.write_all(&fnv1a(&body).to_le_bytes())?;
    Ok(())
}

/// Load a model from `path`, verifying structure and checksum.
pub fn load_model(path: &Path) -> anyhow::Result<SldaModel> {
    let mut f = BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic).context("reading magic")?;
    if &magic != MAGIC {
        bail!("{path:?} is not a cfslda model (bad magic)");
    }
    let mut rest = Vec::new();
    f.read_to_end(&mut rest)?;
    if rest.len() < 8 {
        bail!("truncated model file");
    }
    let (body, ck) = rest.split_at(rest.len() - 8);
    let want = u64::from_le_bytes(ck.try_into().unwrap());
    if fnv1a(body) != want {
        bail!("model checksum mismatch — corrupted file");
    }

    let mut off = 0usize;
    let mut take = |n: usize| -> anyhow::Result<&[u8]> {
        if off + n > body.len() {
            bail!("truncated model body");
        }
        let s = &body[off..off + n];
        off += n;
        Ok(s)
    };
    let t = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
    let w = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
    if t == 0 || w == 0 || t > 1 << 16 || w > 1 << 28 {
        bail!("implausible model dims t={t} w={w}");
    }
    let rho = f64::from_le_bytes(take(8)?.try_into().unwrap());
    let alpha = f64::from_le_bytes(take(8)?.try_into().unwrap());
    let train_mse = f64::from_le_bytes(take(8)?.try_into().unwrap());
    let train_acc = f64::from_le_bytes(take(8)?.try_into().unwrap());
    let mut eta = Vec::with_capacity(t);
    for _ in 0..t {
        eta.push(f64::from_le_bytes(take(8)?.try_into().unwrap()));
    }
    let mut phi = Vec::with_capacity(w * t);
    for _ in 0..w * t {
        phi.push(f32::from_le_bytes(take(4)?.try_into().unwrap()));
    }
    if off != body.len() {
        bail!("trailing bytes in model file");
    }
    Ok(SldaModel { t, w, eta, phi, rho, alpha, train_mse, train_acc })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cfslda_model_{}_{name}", std::process::id()));
        p
    }

    fn random_model(t: usize, w: usize, seed: u64) -> SldaModel {
        let mut rng = Pcg64::seed_from_u64(seed);
        SldaModel {
            t,
            w,
            eta: (0..t).map(|_| rng.next_gaussian()).collect(),
            phi: (0..w * t).map(|_| rng.next_f32()).collect(),
            rho: 0.42,
            alpha: 0.3,
            train_mse: 0.1,
            train_acc: 0.9,
        }
    }

    #[test]
    fn roundtrip_exact() {
        let m = random_model(8, 100, 1);
        let p = tmp("rt.bin");
        save_model(&m, &p).unwrap();
        let m2 = load_model(&p).unwrap();
        assert_eq!(m.t, m2.t);
        assert_eq!(m.w, m2.w);
        assert_eq!(m.eta, m2.eta);
        assert_eq!(m.phi, m2.phi);
        assert_eq!(m.rho, m2.rho);
        assert_eq!(m.train_acc, m2.train_acc);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn corruption_detected() {
        let m = random_model(4, 30, 2);
        let p = tmp("corrupt.bin");
        save_model(&m, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        let err = load_model(&p).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn truncation_and_bad_magic_detected() {
        let m = random_model(4, 30, 3);
        let p = tmp("trunc.bin");
        save_model(&m, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 20]).unwrap();
        assert!(load_model(&p).is_err());
        std::fs::write(&p, b"NOTAMODL").unwrap();
        assert!(load_model(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
