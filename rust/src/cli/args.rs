//! Tiny argument parser: a positional command, an optional positional
//! subcommand (`cfslda arena pack …`), then `--key value` flags and bare
//! `--switch`es.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                anyhow::ensure!(!name.is_empty(), "empty flag '--'");
                // value if the next token exists and is not itself a flag
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = it.next().unwrap();
                        out.flags.insert(name.to_string(), v);
                    }
                    _ => out.switches.push(name.to_string()),
                }
            } else if out.command.is_none() {
                out.command = Some(a);
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                anyhow::bail!("unexpected positional argument '{a}'");
            }
        }
        Ok(out)
    }

    pub fn from_env() -> anyhow::Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn command_flags_switches() {
        let a = parse("train --data x.bow --runs 3 --verbose");
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get("data"), Some("x.bow"));
        assert_eq!(a.get_usize("runs", 1).unwrap(), 3);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults_and_types() {
        let a = parse("x");
        assert_eq!(a.get_or("engine", "auto"), "auto");
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
        assert_eq!(a.get_f64("scale", 0.5).unwrap(), 0.5);
        assert_eq!(a.get_u64("seed", 9).unwrap(), 9);
    }

    #[test]
    fn negative_numbers_are_values() {
        let a = parse("gen --shift -1.5");
        assert_eq!(a.get_f64("shift", 0.0).unwrap(), -1.5);
    }

    #[test]
    fn subcommand_is_the_second_positional() {
        let a = parse("arena pack --input x.bow --out x.arena");
        assert_eq!(a.command.as_deref(), Some("arena"));
        assert_eq!(a.subcommand.as_deref(), Some("pack"));
        assert_eq!(a.get("input"), Some("x.bow"));
        // One positional leaves the subcommand empty.
        assert_eq!(parse("train").subcommand, None);
    }

    #[test]
    fn errors() {
        // Two positionals parse (command + subcommand); a third is an error.
        assert!(Args::parse(vec!["a".into(), "b".into(), "c".into()]).is_err());
        assert!(Args::parse(vec!["--".into()]).is_err());
        assert!(parse("x --n seven").get_usize("n", 1).is_err());
    }
}
