//! CLI subcommands. Each returns the process exit code.

use super::args::Args;
use crate::ckpt::{config_fingerprint, GenCoordinator, ShardState, StdFs, Store};
use crate::combine::ShardArtifact;
use crate::config::json::{self, Value};
use crate::config::schema::{
    EngineKind, ExperimentConfig, KernelKind, RespMode, ResponseKind, ServeBackend,
};
use crate::data::arena_file::{pack_file, ArenaMap};
use crate::data::loader;
use crate::data::partition::train_test_split;
use crate::data::stats::{corpus_stats, label_report};
use crate::data::synthetic::{generate_corpus, SyntheticSpec};
use crate::data::tokenizer::TokenizerConfig;
use crate::data::vocab::Vocab;
use crate::experiments::{fig123, fig5, runner};
use crate::model::persist::{load_model, load_model_full, save_model_with_vocab};
use crate::parallel::leader::{run_with_engine_ckpt, Algorithm, CkptPlan, RunOutcome};
use crate::parallel::multiproc::{
    combine_artifacts, load_artifact_dir, run_train_shard, ShardRunOutcome, ShardSpec,
    TrainShardJob,
};
use crate::runtime::EngineHandle;
use crate::sampler::{gibbs_predict, gibbs_train};
use crate::serve::bench::{run_bench, BenchOptions};
use crate::serve::server::{run_blocking, RunOptions};
use crate::util::rng::Pcg64;
use crate::util::signal;
use crate::util::timer::Stopwatch;
use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicBool;

pub const HELP: &str = "\
cfslda — communication-free parallel supervised topic models

USAGE: cfslda <command> [flags]

COMMANDS:
  gen-data    Generate a synthetic sLDA corpus
              --out FILE.bow  --preset small|binary|mdna|imdb  [--docs N]
              [--vocab N] [--topics N] [--seed S]
  inspect     Corpus statistics + label histogram (Fig-5 style)
              --data FILE.bow [--bins N]
  run         Run one algorithm on a corpus
              --data FILE.bow --algorithm non-parallel|naive|simple|weighted|median
              [--train N] [--config CFG.json] [--engine auto|xla|native]
              [--kernel dense|sparse|alias|auto] [--alias-staleness N]
              [--resp-mode exact|mh|auto] [--seed S] [--json OUT.json]
              [--checkpoint-every N] [--checkpoint-dir D] [--resume D]
  train       Train a single sLDA model and save it
              --data FILE.bow|FILE.jsonl --out MODEL.bin [--config CFG.json]
              [--seed S] [--kernel dense|sparse|alias|auto] [--alias-staleness N]
              [--resp-mode exact|mh|auto] [--vocab TERMS.txt]
              [--min-df F] [--max-df F]
              [--checkpoint-every N] [--checkpoint-dir D] [--resume D]
              Crash safety (DESIGN.md §Durability): --checkpoint-every N
              snapshots the full sampler state (per-shard z, counts, eta,
              RNG) into --checkpoint-dir D every N sweeps, written
              atomically (temp + fsync + rename; the two newest
              generations are retained). SIGINT/SIGTERM exit cleanly at
              the next boundary after a final snapshot. --resume D
              restores the newest valid generation and continues: the
              finished model is byte-identical to the same run left
              uninterrupted. The cadence is part of the chain — pass the
              same --checkpoint-every (and config/seed/corpus) when
              resuming; a mismatch is rejected via a config fingerprint.
              --resp-mode picks the supervised (eta-active) sweep: exact =
              dense O(T)/token Gaussian conditional on every kernel; mh =
              the kernel's own sparse/alias proposals with an O(1)
              Metropolis-Hastings response correction; auto = exact for
              dense, mh for sparse/alias.
              A .jsonl corpus ({\"text\", \"response\"} lines) is tokenized
              here and the learned vocabulary is persisted into the model,
              enabling serve's /predict/text and named top-words. For .bow
              corpora pass --vocab (one term per line, id order) to attach
              terms.
  predict     Predict with a saved model
              --model MODEL.bin --data FILE.bow [--kernel dense|sparse|alias|auto]
              [--jobs N] [--seed S] [--json OUT.json]
              Documents are seeded individually (content-addressed), so the
              output is identical for any --jobs and matches `cfslda serve`
              for the same (model, seed, doc).
  top-words   Show each topic's highest-probability terms (word ids when
              the model has no vocabulary)
              --model MODEL.bin [--k N]
  serve       Long-lived prediction server (DESIGN.md §Serving)
              --model MODEL.bin [--addr HOST:PORT] [--port N]
              [--workers N] [--max-batch N] [--max-wait-us N] [--cache N]
              [--seed S] [--kernel K] [--config CFG.json] [--port-file F]
              [--backend threads|epoll] [--max-conns N]
              [--queue-depth-max N] [--idle-timeout-ms N]
              [--read-timeout-ms N]
              --backend picks the connection engine: threads = one
              blocking handler thread per connection (portable
              fallback); epoll = one non-blocking readiness event loop
              for all connections (10k+ concurrent, Linux only).
              Responses are byte-identical across backends for the same
              (model, seed, doc).
              Admission control: past --max-conns open connections (0 =
              unlimited) or a full prediction queue (--queue-depth-max
              items, 0 = unbounded) requests are shed with
              503 + Retry-After. Idle keep-alive connections are reaped
              after --idle-timeout-ms; a request (headers+body) that
              takes longer than --read-timeout-ms to arrive is timed out
              (0 disables either timer).
              Endpoints: POST /predict {\"docs\": [[id, ...], ...]},
              POST /predict/text {\"texts\": [\"...\"]}, POST /reload
              [{\"path\": \"new.bin\"}], GET /healthz, GET /stats,
              GET /metrics (Prometheus text format).
              Quickstart:
                cfslda train --data corpus.bow --out m.bin
                cfslda serve --model m.bin --port 7878 &
                curl -d '{\"docs\": [[0, 4, 4]]}' localhost:7878/predict
  serve-bench Loopback load harness; writes BENCH_serve.json with
              before/after docs/s per kernel (default sparse,alias) plus
              a connection-scaling sweep (latency quantiles + shed_rate
              per backend at each --conns-list count)
              --model MODEL.bin [--quick] [--workers-list 1,2,4]
              [--batch-list 1,8] [--kernel-list sparse,alias] [--clients N]
              [--requests N] [--conns-list 64,1024,4096]
              [--backend-list threads,epoll] [--json F]
  arena pack  Pack a corpus into the mmap-ready CFSARENA1 token arena
              (DESIGN.md §Out-of-core; streams the input, O(docs) RAM)
              --input FILE.bow|FILE.jsonl --out FILE.arena
  train-shard Train one shard of an M-process communication-free run over
              an mmapped arena and persist its artifact
              --arena FILE.arena --shard j/M
              [--algorithm simple|weighted|median] [--train N]
              [--out FILE.shrd | --out-dir DIR] [--config CFG.json]
              [--engine E] [--kernel K] [--seed S] [--json OUT.json]
              [--checkpoint-every N] [--checkpoint-dir D] [--resume D]
              Every process replays the leader's RNG plan, so M such
              processes + `combine` are byte-identical to `run` with the
              same seed/--train and `[parallel] shards = M`. The shard
              processes share the arena read-only through the page cache
              and never talk: setup copies zero bytes.
  combine     Combine M shard artifacts into the global prediction
              --dir DIR [--engine E] [--json OUT.json]
  experiment  Four-algorithm comparison (paper Fig 6 / Fig 7)
              --fig 6|7 [--scale F] [--runs N] [--engine E]
              [--kernel dense|sparse|alias|auto] [--resp-mode exact|mh|auto]
              [--heartbeat-secs F] [--check]
              [--checkpoint-every N] [--checkpoint-dir D] [--resume D]
              Each (algorithm, run) leg checkpoints independently;
              --resume continues an interrupted comparison, fast-replaying
              the legs already finished.
  figs        Reproduce illustration figures: --fig 1|2|3|5
  help        This text

ENVIRONMENT:
  CFSLDA_ARTIFACTS  artifacts directory (default ./artifacts)
  CFSLDA_LOG        off|error|warn|info|debug|trace
";

fn spec_from_args(a: &Args) -> anyhow::Result<SyntheticSpec> {
    let mut spec = match a.get_or("preset", "small") {
        "small" => SyntheticSpec::continuous_small(),
        "binary" => SyntheticSpec::binary_small(),
        "mdna" => SyntheticSpec::mdna(),
        "imdb" => SyntheticSpec::imdb(),
        other => anyhow::bail!("unknown preset '{other}'"),
    };
    if let Some(d) = a.get("docs") {
        spec.docs = d.parse()?;
    }
    if let Some(v) = a.get("vocab") {
        spec.vocab = v.parse()?;
    }
    if let Some(t) = a.get("topics") {
        spec.topics = t.parse()?;
    }
    Ok(spec)
}

/// Apply the shared `--kernel dense|sparse|alias|auto` flag (plus the alias
/// kernel's `--alias-staleness` rebuild budget and the supervised-sweep
/// `--resp-mode exact|mh|auto` knob) to a config.
fn apply_kernel_flag(a: &Args, cfg: &mut ExperimentConfig) -> anyhow::Result<()> {
    if let Some(k) = a.get("kernel") {
        cfg.sampler.kernel = KernelKind::parse(k)?;
    }
    cfg.sampler.alias_staleness =
        a.get_usize("alias-staleness", cfg.sampler.alias_staleness)?;
    if let Some(r) = a.get("resp-mode") {
        cfg.sampler.resp_mode = RespMode::parse(r)?;
    }
    Ok(())
}

/// Apply the shared crash-safety flags (`--checkpoint-every N`,
/// `--checkpoint-dir D`, `--resume D`) to a config. Returns whether the run
/// should restore the newest valid checkpoint. `--resume` implies the
/// checkpoint directory; the cadence must still match the interrupted run
/// (it is part of the chain and of the config fingerprint).
fn apply_ckpt_flags(a: &Args, cfg: &mut ExperimentConfig) -> anyhow::Result<bool> {
    cfg.train.checkpoint_every = a.get_usize("checkpoint-every", cfg.train.checkpoint_every)?;
    if let Some(d) = a.get("checkpoint-dir") {
        cfg.train.checkpoint_dir = d.to_string();
    }
    let Some(dir) = a.get("resume") else { return Ok(false) };
    if let Some(explicit) = a.get("checkpoint-dir") {
        anyhow::ensure!(
            explicit == dir,
            "--resume {dir} conflicts with --checkpoint-dir {explicit}; pass one"
        );
    }
    cfg.train.checkpoint_dir = dir.to_string();
    anyhow::ensure!(
        cfg.train.checkpoint_every > 0,
        "--resume needs the original run's cadence: pass --checkpoint-every N \
         (or set train.checkpoint_every) to the value the interrupted run used"
    );
    Ok(true)
}

fn ckpt_enabled(cfg: &ExperimentConfig) -> bool {
    cfg.train.checkpoint_every > 0 && !cfg.train.checkpoint_dir.is_empty()
}

/// Resolve the stop flag for a checkpoint-enabled run: tests inject their
/// own [`AtomicBool`]; the real CLI installs the SIGINT/SIGTERM handler and
/// polls the process-global shutdown flag.
fn stop_flag<'s>(stop_override: Option<&'s AtomicBool>) -> anyhow::Result<&'s AtomicBool> {
    match stop_override {
        Some(flag) => Ok(flag),
        None => {
            signal::install_shutdown_handler()?;
            Ok(signal::shutdown_flag())
        }
    }
}

fn engine_from_args(a: &Args) -> anyhow::Result<EngineHandle> {
    let kind = EngineKind::parse(a.get_or("engine", "auto"))?;
    let dir = std::env::var("CFSLDA_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    EngineHandle::from_kind(kind, Path::new(&dir))
}

pub fn cmd_gen_data(a: &Args) -> anyhow::Result<i32> {
    let out = a.get("out").ok_or_else(|| anyhow::anyhow!("--out is required"))?;
    let spec = spec_from_args(a)?;
    let seed = a.get_u64("seed", 20170710)?;
    let mut rng = Pcg64::seed_from_u64(seed);
    let corpus = generate_corpus(&spec, &mut rng);
    loader::save_bow(&corpus, Path::new(out))?;
    let s = corpus_stats(&corpus);
    println!(
        "wrote {}: docs={} tokens={} vocab={} mean_len={:.1}",
        out, s.docs, s.tokens, s.vocab, s.mean_doc_len
    );
    Ok(0)
}

pub fn cmd_inspect(a: &Args) -> anyhow::Result<i32> {
    let data = a.get("data").ok_or_else(|| anyhow::anyhow!("--data is required"))?;
    let corpus = loader::load_bow(Path::new(data))?;
    let s = corpus_stats(&corpus);
    println!(
        "docs={} tokens={} vocab={} doc_len[min/mean/max]={}/{:.1}/{}",
        s.docs, s.tokens, s.vocab, s.min_doc_len, s.mean_doc_len, s.max_doc_len
    );
    let bins = a.get_usize("bins", 30)?;
    println!("{}", label_report(&corpus, bins).render("label distribution"));
    Ok(0)
}

pub fn cmd_run(a: &Args) -> anyhow::Result<i32> {
    cmd_run_with_stop(a, None)
}

/// [`cmd_run`] with an injectable stop flag, so tests can interrupt runs
/// deterministically without touching the process-global signal state.
fn cmd_run_with_stop(a: &Args, stop_override: Option<&AtomicBool>) -> anyhow::Result<i32> {
    let data = a.get("data").ok_or_else(|| anyhow::anyhow!("--data is required"))?;
    let algo = Algorithm::parse(a.get_or("algorithm", "simple-average"))?;
    let corpus = loader::load_bow(Path::new(data))?;
    let mut cfg = match a.get("config") {
        Some(p) => ExperimentConfig::load(p)?,
        None => ExperimentConfig::default(),
    };
    if let Some(e) = a.get("engine") {
        cfg.engine = EngineKind::parse(e)?;
    }
    apply_kernel_flag(a, &mut cfg)?;
    cfg.seed = a.get_u64("seed", cfg.seed)?;
    let resume = apply_ckpt_flags(a, &mut cfg)?;
    let n_train = a.get_usize("train", corpus.num_docs() * 3 / 4)?;
    let mut rng = Pcg64::seed_from_u64(cfg.seed ^ 0x5911_7001);
    let ds = train_test_split(&corpus, n_train, &mut rng);
    let engine = engine_from_args(a)?;
    let plan = if ckpt_enabled(&cfg) {
        Some(CkptPlan { resume, stop: Some(stop_flag(stop_override)?) })
    } else {
        None
    };
    let (out, _) = match run_with_engine_ckpt(algo, &ds, &cfg, &engine, false, plan)? {
        RunOutcome::Done(both) => *both,
        RunOutcome::Interrupted { next_sweep } => {
            println!(
                "interrupted cleanly at checkpoint boundary (sweep {next_sweep} of {}); \
                 state saved under {}",
                cfg.train.sweeps, cfg.train.checkpoint_dir
            );
            println!(
                "resume with: cfslda run --data {data} --algorithm {} \
                 --checkpoint-every {} --resume {}",
                algo.name(),
                cfg.train.checkpoint_every,
                cfg.train.checkpoint_dir
            );
            return Ok(0);
        }
    };
    let binary = cfg.response == ResponseKind::Binary;
    println!(
        "{}: wall={:.2}s {} comm[{}]",
        algo.name(),
        out.wall_secs,
        out.test_metrics.render(binary),
        out.comm.render()
    );
    println!("phases: {}", out.timings.render());
    if let Some(path) = a.get("json") {
        let v = Value::object(vec![
            ("algorithm", Value::String(algo.name().into())),
            ("wall_secs", Value::Number(out.wall_secs)),
            ("mse", Value::Number(out.test_metrics.mse)),
            ("acc", Value::Number(out.test_metrics.acc)),
            ("r2", Value::Number(out.test_metrics.r2)),
            ("n_test", Value::Number(out.test_metrics.n as f64)),
            ("yhat", Value::from_f64_slice(&out.yhat)),
        ]);
        std::fs::write(path, json::to_string_pretty(&v))?;
        println!("metrics written to {path}");
    }
    Ok(0)
}

/// `cfslda arena <subcommand>`: out-of-core arena tooling. `pack` streams
/// a corpus into the mmap-ready `CFSARENA1` format.
pub fn cmd_arena(a: &Args) -> anyhow::Result<i32> {
    match a.subcommand.as_deref() {
        Some("pack") => {
            let input = a.get("input").ok_or_else(|| anyhow::anyhow!("--input is required"))?;
            let out = a.get("out").ok_or_else(|| anyhow::anyhow!("--out is required"))?;
            let s = pack_file(Path::new(input), Path::new(out))?;
            println!(
                "packed {input} -> {out}: docs={} tokens={} vocab={} (skipped {} empty)",
                s.docs, s.tokens, s.vocab, s.skipped_empty
            );
            Ok(0)
        }
        other => anyhow::bail!(
            "usage: cfslda arena pack --input FILE --out FILE.arena (got subcommand '{}')",
            other.unwrap_or("<none>")
        ),
    }
}

pub fn cmd_train_shard(a: &Args) -> anyhow::Result<i32> {
    cmd_train_shard_with_stop(a, None)
}

/// [`cmd_train_shard`] with an injectable stop flag (see
/// [`cmd_run_with_stop`]).
fn cmd_train_shard_with_stop(
    a: &Args,
    stop_override: Option<&AtomicBool>,
) -> anyhow::Result<i32> {
    let arena_path = a.get("arena").ok_or_else(|| anyhow::anyhow!("--arena is required"))?;
    let spec = ShardSpec::parse(
        a.get("shard").ok_or_else(|| anyhow::anyhow!("--shard j/M is required"))?,
    )?;
    let algo = Algorithm::parse(a.get_or("algorithm", "simple-average"))?;
    let mut cfg = match a.get("config") {
        Some(p) => ExperimentConfig::load(p)?,
        None => ExperimentConfig::default(),
    };
    if let Some(e) = a.get("engine") {
        cfg.engine = EngineKind::parse(e)?;
    }
    apply_kernel_flag(a, &mut cfg)?;
    cfg.seed = a.get_u64("seed", cfg.seed)?;
    let resume = apply_ckpt_flags(a, &mut cfg)?;
    let arena = ArenaMap::open(Path::new(arena_path))?;
    let n_train = a.get_usize("train", arena.num_docs() * 3 / 4)?;
    let out = match a.get("out") {
        Some(p) => PathBuf::from(p),
        None => PathBuf::from(a.get_or("out-dir", "."))
            .join(ShardArtifact::file_name(spec.shard as u32, spec.m as u32)),
    };
    let engine = engine_from_args(a)?;
    let stop = if ckpt_enabled(&cfg) { Some(stop_flag(stop_override)?) } else { None };
    let outcome = run_train_shard(TrainShardJob {
        arena: &arena,
        cfg: &cfg,
        engine: &engine,
        algo,
        spec,
        n_train,
        out: out.clone(),
        resume,
        stop,
    })?;
    let (artifact, comm) = match outcome {
        ShardRunOutcome::Done { artifact, comm } => (artifact, comm),
        ShardRunOutcome::Interrupted { next_sweep } => {
            println!(
                "interrupted cleanly at checkpoint boundary (sweep {next_sweep} of {}); \
                 state saved under {}",
                cfg.train.sweeps, cfg.train.checkpoint_dir
            );
            println!(
                "resume with: cfslda train-shard --arena {arena_path} --shard {}/{} \
                 --algorithm {} --checkpoint-every {} --resume {}",
                spec.shard,
                spec.m,
                algo.name(),
                cfg.train.checkpoint_every,
                cfg.train.checkpoint_dir
            );
            return Ok(0);
        }
    };
    println!(
        "shard {}/{}: trained {} docs ({} tokens sampled), artifact {}",
        spec.shard,
        spec.m,
        artifact.docs,
        artifact.tokens_sampled,
        out.display()
    );
    println!("comm[{}]", comm.render());
    if let Some(path) = a.get("json") {
        let v = Value::object(vec![
            ("shard", Value::Number(spec.shard as f64)),
            ("m", Value::Number(spec.m as f64)),
            ("docs", Value::Number(artifact.docs as f64)),
            ("tokens_sampled", Value::Number(artifact.tokens_sampled as f64)),
            ("setup_copied_bytes", Value::Number(comm.setup_copied_bytes as f64)),
            ("setup_referenced_bytes", Value::Number(comm.setup_referenced_bytes as f64)),
            ("gather_bytes", Value::Number(comm.gather_bytes as f64)),
            ("sampling_syncs", Value::Number(comm.sampling_syncs as f64)),
            ("artifact", Value::String(out.display().to_string())),
        ]);
        std::fs::write(path, json::to_string_pretty(&v))?;
    }
    Ok(0)
}

pub fn cmd_combine(a: &Args) -> anyhow::Result<i32> {
    let dir = a.get("dir").ok_or_else(|| anyhow::anyhow!("--dir is required"))?;
    let artifacts = load_artifact_dir(Path::new(dir))?;
    let engine = engine_from_args(a)?;
    let out = combine_artifacts(&engine, &artifacts)?;
    let binary = artifacts[0].response == ResponseKind::Binary;
    println!(
        "{} ({} shards): {} comm[{}]",
        out.algorithm.name(),
        artifacts.len(),
        out.test_metrics.render(binary),
        out.comm.render()
    );
    if let Some(path) = a.get("json") {
        let v = Value::object(vec![
            ("algorithm", Value::String(out.algorithm.name().into())),
            ("shards", Value::Number(artifacts.len() as f64)),
            ("mse", Value::Number(out.test_metrics.mse)),
            ("acc", Value::Number(out.test_metrics.acc)),
            ("r2", Value::Number(out.test_metrics.r2)),
            ("n_test", Value::Number(out.test_metrics.n as f64)),
            ("yhat", Value::from_f64_slice(&out.yhat)),
            ("weights", Value::from_f64_slice(&out.weights)),
            ("setup_copied_bytes", Value::Number(out.comm.setup_copied_bytes as f64)),
            ("gather_bytes", Value::Number(out.comm.gather_bytes as f64)),
        ]);
        std::fs::write(path, json::to_string_pretty(&v))?;
        println!("metrics written to {path}");
    }
    Ok(0)
}

pub fn cmd_experiment(a: &Args) -> anyhow::Result<i32> {
    let fig = a.get_usize("fig", 6)?;
    let scale = a.get_f64("scale", 0.25)?;
    let runs = a.get_usize("runs", 3)?;
    let mut c = match fig {
        6 => runner::Comparison::fig6(scale, runs),
        7 => runner::Comparison::fig7(scale, runs),
        other => anyhow::bail!("--fig must be 6 or 7, got {other}"),
    };
    if let Some(e) = a.get("engine") {
        c.cfg.engine = EngineKind::parse(e)?;
    }
    if let Some(t) = a.get("topics") {
        c.cfg.model.topics = t.parse()?;
    }
    if let Some(s) = a.get("sweeps") {
        c.cfg.train.sweeps = s.parse()?;
    }
    apply_kernel_flag(a, &mut c.cfg)?;
    let resume = apply_ckpt_flags(a, &mut c.cfg)?;
    // Training progress heartbeat (structured JSON info line every F
    // seconds; 0 = off) — see DESIGN.md §Observability.
    c.cfg.obs.heartbeat_secs = a.get_f64("heartbeat-secs", c.cfg.obs.heartbeat_secs)?;
    crate::config::validate::validate(&c.cfg)?;
    let engine = engine_from_args(a)?;
    let binary = fig == 7;
    let ckpt = if ckpt_enabled(&c.cfg) {
        Some(runner::ComparisonCkpt { resume, stop: Some(stop_flag(None)?) })
    } else {
        None
    };
    let (series, _) = match runner::run_comparison_ckpt(&c, &engine, ckpt)? {
        runner::ComparisonRun::Done(both) => *both,
        runner::ComparisonRun::Interrupted { algorithm, run, next_sweep } => {
            println!(
                "interrupted cleanly at checkpoint boundary ({} run {run}, sweep {next_sweep}); \
                 rerun the same command with --resume {} to continue",
                algorithm.name(),
                c.cfg.train.checkpoint_dir
            );
            return Ok(0);
        }
    };
    let title = if binary {
        format!("Fig 7: reviews -> sentiment (docs={} runs={})", c.spec.docs, runs)
    } else {
        format!("Fig 6: MD&A -> EPS (docs={} runs={})", c.spec.docs, runs)
    };
    println!("{}", runner::render_table(&title, &series, binary));
    if a.has("check") {
        runner::check_fig_shape(&series, binary)?;
        println!("shape check PASSED (naive worst; simple fast + accurate; weighted slowest parallel arm)");
    }
    Ok(0)
}

pub fn cmd_figs(a: &Args) -> anyhow::Result<i32> {
    let fig = a.get_usize("fig", 0)?;
    let seed = a.get_u64("seed", 20170710)?;
    match fig {
        1 => {
            let d = fig123::fig1_unimodal(3, 20_000, seed);
            println!(
                "Fig 1 (unimodal pooling): KS(pooled,true)={:.4} mean-single={:.4}",
                d.ks_pooled, d.ks_single_mean
            );
        }
        2 => {
            let d = fig123::fig2_multimodal(20_000, seed);
            println!(
                "Fig 2 (multimodal pooling): KS(pooled,true)={:.4} basins={:?}",
                d.ks_pooled, d.basin_mass
            );
        }
        3 => {
            let spec = SyntheticSpec::continuous_small();
            let mut rng = Pcg64::seed_from_u64(seed);
            let corpus = generate_corpus(&spec, &mut rng);
            let ds = train_test_split(&corpus, spec.docs * 3 / 4, &mut rng);
            let mut cfg = ExperimentConfig::quick();
            cfg.seed = seed;
            let engine = engine_from_args(a)?;
            let r = fig123::fig3_projection(&ds, &cfg, &engine)?;
            let f1 = fig123::fig1_unimodal(3, 5_000, seed);
            let f2 = fig123::fig2_multimodal(5_000, seed);
            println!("{}", fig123::render(&f1, &f2, &r));
        }
        5 => {
            // Fig 5 is about the Experiment-I (MD&A/EPS) label distribution.
            let spec = if a.get("preset").is_some() || a.get("docs").is_some() {
                spec_from_args(a)?
            } else {
                SyntheticSpec::mdna()
            };
            let r = fig5::fig5_labels(&spec, a.get_usize("bins", 40)?, seed);
            println!("{}", fig5::render(&r, &spec));
        }
        other => anyhow::bail!("--fig must be one of 1|2|3|5, got {other}"),
    }
    Ok(0)
}

/// Load a training corpus, producing a vocabulary when one is available:
/// raw-text `.jsonl` corpora build it during tokenization; `.bow` corpora
/// can attach one via `--vocab TERMS.txt` (one term per line, id order).
fn load_train_corpus(
    a: &Args,
    data: &str,
) -> anyhow::Result<(crate::data::corpus::Corpus, Option<Vocab>)> {
    if data.ends_with(".jsonl") {
        let min_df = a.get_f64("min-df", 0.02)?; // the paper's 2% floor
        let max_df = a.get_f64("max-df", 1.0)?;
        let (corpus, vocab) =
            loader::load_text_jsonl(Path::new(data), &TokenizerConfig::default(), min_df, max_df)?;
        return Ok((corpus, Some(vocab)));
    }
    let corpus = loader::load_bow(Path::new(data))?;
    let vocab = match a.get("vocab") {
        None => None,
        Some(vp) => {
            let text = std::fs::read_to_string(vp)
                .map_err(|e| anyhow::anyhow!("reading --vocab {vp}: {e}"))?;
            let terms: Vec<String> =
                text.lines().map(|l| l.trim().to_string()).filter(|l| !l.is_empty()).collect();
            anyhow::ensure!(
                terms.len() == corpus.vocab_size,
                "--vocab has {} terms but the corpus vocab size is {}",
                terms.len(),
                corpus.vocab_size
            );
            Some(Vocab::from_terms(terms)?)
        }
    };
    Ok((corpus, vocab))
}

pub fn cmd_train(a: &Args) -> anyhow::Result<i32> {
    cmd_train_with_stop(a, None)
}

/// [`cmd_train`] with an injectable stop flag (see [`cmd_run_with_stop`]).
fn cmd_train_with_stop(a: &Args, stop_override: Option<&AtomicBool>) -> anyhow::Result<i32> {
    let data = a.get("data").ok_or_else(|| anyhow::anyhow!("--data is required"))?;
    let out = a.get("out").ok_or_else(|| anyhow::anyhow!("--out is required"))?;
    let (corpus, vocab) = load_train_corpus(a, data)?;
    let mut cfg = match a.get("config") {
        Some(p) => ExperimentConfig::load(p)?,
        None => ExperimentConfig::default(),
    };
    cfg.seed = a.get_u64("seed", cfg.seed)?;
    if let Some(e) = a.get("engine") {
        cfg.engine = EngineKind::parse(e)?;
    }
    apply_kernel_flag(a, &mut cfg)?;
    let resume = apply_ckpt_flags(a, &mut cfg)?;
    crate::config::validate::validate(&cfg)?;
    let engine = engine_from_args(a)?;
    let mut rng = Pcg64::seed_from_u64(cfg.seed);
    let trained = if ckpt_enabled(&cfg) {
        let stop = stop_flag(stop_override)?;
        let fs = StdFs;
        // `cfslda train` is one full-corpus chain: algorithm "train",
        // one shard.
        let fingerprint = config_fingerprint(
            &cfg,
            corpus.num_docs(),
            corpus.num_tokens(),
            corpus.vocab_size,
            "train",
            1,
        );
        let dir = Path::new(&cfg.train.checkpoint_dir).join(format!("train-seed{}", cfg.seed));
        let store = Store::new(&fs, dir);
        let coord = GenCoordinator::new(1, fingerprint);
        let resume_state = if resume {
            let mut r = store.load_latest(fingerprint)?;
            anyhow::ensure!(
                r.states.len() == 1,
                "checkpoint in {} holds {} shard states; `cfslda train` is single-chain",
                store.dir().display(),
                r.states.len()
            );
            println!(
                "resuming from checkpoint generation {} (sweep {} of {}) in {}",
                r.generation,
                r.next_sweep,
                cfg.train.sweeps,
                store.dir().display()
            );
            Some(r.states.remove(0))
        } else {
            None
        };
        let sink = |state: ShardState| -> anyhow::Result<()> {
            let sw = Stopwatch::new();
            let generation = state.next_sweep;
            let entry = store.write_shard(generation, &state)?;
            let write_us = (sw.elapsed_secs() * 1e6) as u64;
            if let Some((manifest, total_us)) = coord.shard_done(generation, entry, write_us) {
                store.commit_manifest(generation, &manifest, total_us)?;
            }
            Ok(())
        };
        let hook = gibbs_train::CkptHook {
            shard_id: 0,
            resume: resume_state,
            sink: Some(&sink),
            stop: Some(stop),
        };
        match gibbs_train::train_ckpt(&corpus, &cfg, &engine, &mut rng, Some(hook))? {
            gibbs_train::TrainRun::Done(done) => *done,
            gibbs_train::TrainRun::Interrupted { next_sweep } => {
                println!(
                    "interrupted cleanly at checkpoint boundary (sweep {next_sweep} of {}); \
                     state saved in {}",
                    cfg.train.sweeps,
                    store.dir().display()
                );
                println!(
                    "resume with: cfslda train --data {data} --out {out} \
                     --checkpoint-every {} --resume {}",
                    cfg.train.checkpoint_every, cfg.train.checkpoint_dir
                );
                return Ok(0);
            }
        }
    } else {
        gibbs_train::train(&corpus, &cfg, &engine, &mut rng)?
    };
    save_model_with_vocab(&trained.model, vocab.as_ref(), Path::new(out))?;
    println!(
        "trained T={} on {} docs ({} tokens, {} sweeps): in-sample mse={:.4} acc={:.4}",
        trained.model.t,
        corpus.num_docs(),
        corpus.num_tokens(),
        cfg.train.sweeps,
        trained.model.train_mse,
        trained.model.train_acc,
    );
    match &vocab {
        Some(v) => println!("model saved to {out} (with {}-term vocabulary)", v.len()),
        None => println!("model saved to {out}"),
    }
    Ok(0)
}

pub fn cmd_predict(a: &Args) -> anyhow::Result<i32> {
    let model_path = a.get("model").ok_or_else(|| anyhow::anyhow!("--model is required"))?;
    let data = a.get("data").ok_or_else(|| anyhow::anyhow!("--data is required"))?;
    let model = load_model(Path::new(model_path))?;
    let corpus = loader::load_bow(Path::new(data))?;
    anyhow::ensure!(
        corpus.vocab_size <= model.w,
        "corpus vocab {} exceeds model vocab {}",
        corpus.vocab_size,
        model.w
    );
    let mut cfg = ExperimentConfig::default();
    apply_kernel_flag(a, &mut cfg)?;
    let engine = engine_from_args(a)?;
    let seed = a.get_u64("seed", 20170710)?;
    let jobs = a.get_usize("jobs", 1)?;
    anyhow::ensure!(jobs >= 1, "--jobs must be >= 1");
    let ys = corpus.responses();
    // Per-document seeded streams: the result is identical for any --jobs
    // (and matches `cfslda serve` for the same model/seed/doc).
    let (pred, _) = gibbs_predict::predict_corpus_parallel(
        &model, &corpus, &cfg.train, cfg.sampler.kernel, &engine, Some(&ys), seed, jobs,
    )?;
    println!(
        "predicted {} documents (jobs={jobs}): mse={:.4} acc={:.4}",
        pred.yhat.len(),
        pred.mse,
        pred.acc
    );
    if let Some(path) = a.get("json") {
        let v = Value::object(vec![
            ("yhat", Value::from_f64_slice(&pred.yhat)),
            ("mse", Value::Number(pred.mse)),
            ("acc", Value::Number(pred.acc)),
        ]);
        std::fs::write(path, json::to_string_pretty(&v))?;
        println!("predictions written to {path}");
    } else {
        for (i, y) in pred.yhat.iter().take(10).enumerate() {
            println!("  doc {i}: {y:.4}");
        }
        if pred.yhat.len() > 10 {
            println!("  ... ({} more; use --json for all)", pred.yhat.len() - 10);
        }
    }
    Ok(0)
}

pub fn cmd_top_words(a: &Args) -> anyhow::Result<i32> {
    let model_path = a.get("model").ok_or_else(|| anyhow::anyhow!("--model is required"))?;
    let k = a.get_usize("k", 10)?;
    let (model, vocab) = load_model_full(Path::new(model_path))?;
    println!("model: T={} W={} rho={:.4} |eta|={:.3}", model.t, model.w, model.rho,
             model.eta.iter().map(|e| e * e).sum::<f64>().sqrt());
    for t in 0..model.t {
        let tops = model.top_words(t, k);
        let rendered: Vec<String> = tops
            .iter()
            .map(|&w| {
                let name = vocab
                    .as_ref()
                    .and_then(|v| v.term(w))
                    .map(|s| s.to_string())
                    .unwrap_or_else(|| w.to_string());
                format!("{name}:{:.4}", model.phi[w as usize * model.t + t])
            })
            .collect();
        println!("topic {t:>3} (eta {:+.3}): {}", model.eta[t], rendered.join(" "));
    }
    Ok(0)
}

/// Resolve serve-related flags onto the config (shared by serve/serve-bench).
fn serve_cfg_from_args(a: &Args) -> anyhow::Result<ExperimentConfig> {
    let mut cfg = match a.get("config") {
        Some(p) => ExperimentConfig::load(p)?,
        None => ExperimentConfig::default(),
    };
    apply_kernel_flag(a, &mut cfg)?;
    cfg.seed = a.get_u64("seed", cfg.seed)?;
    if let Some(addr) = a.get("addr") {
        cfg.serve.addr = addr.to_string();
    }
    if let Some(port) = a.get("port") {
        let port: u16 = port.parse().map_err(|_| anyhow::anyhow!("--port expects 0..=65535"))?;
        // Override only the port, preserving the host from --addr / config.
        let host = cfg
            .serve
            .addr
            .rsplit_once(':')
            .map(|(h, _)| h.to_string())
            .unwrap_or_else(|| "127.0.0.1".to_string());
        cfg.serve.addr = format!("{host}:{port}");
    }
    cfg.serve.workers = a.get_usize("workers", cfg.serve.workers)?;
    cfg.serve.max_batch = a.get_usize("max-batch", cfg.serve.max_batch)?;
    cfg.serve.max_wait_us = a.get_u64("max-wait-us", cfg.serve.max_wait_us)?;
    cfg.serve.cache_capacity = a.get_usize("cache", cfg.serve.cache_capacity)?;
    if let Some(b) = a.get("backend") {
        cfg.serve.backend = ServeBackend::parse(b)?;
    }
    cfg.serve.max_conns = a.get_usize("max-conns", cfg.serve.max_conns)?;
    cfg.serve.queue_depth_max = a.get_usize("queue-depth-max", cfg.serve.queue_depth_max)?;
    cfg.serve.idle_timeout_ms = a.get_u64("idle-timeout-ms", cfg.serve.idle_timeout_ms)?;
    cfg.serve.read_timeout_ms = a.get_u64("read-timeout-ms", cfg.serve.read_timeout_ms)?;
    crate::config::validate::validate(&cfg)?;
    Ok(cfg)
}

pub fn cmd_serve(a: &Args) -> anyhow::Result<i32> {
    let model = a.get("model").ok_or_else(|| anyhow::anyhow!("--model is required"))?;
    let cfg = serve_cfg_from_args(a)?;
    run_blocking(RunOptions {
        model_path: PathBuf::from(model),
        cfg,
        port_file: a.get("port-file").map(PathBuf::from),
    })?;
    Ok(0)
}

fn parse_usize_list(s: &str, flag: &str) -> anyhow::Result<Vec<usize>> {
    s.split(',')
        .map(|x| {
            x.trim()
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("--{flag} expects comma-separated integers, got '{x}'"))
        })
        .collect()
}

pub fn cmd_serve_bench(a: &Args) -> anyhow::Result<i32> {
    let model = a.get("model").ok_or_else(|| anyhow::anyhow!("--model is required"))?;
    let cfg = serve_cfg_from_args(a)?;
    let quick = a.has("quick");
    let mut opts = BenchOptions::new(PathBuf::from(model), quick);
    if let Some(w) = a.get("workers-list") {
        opts.workers_list = parse_usize_list(w, "workers-list")?;
    }
    if let Some(b) = a.get("batch-list") {
        opts.batch_list = parse_usize_list(b, "batch-list")?;
    }
    if let Some(k) = a.get("kernel-list") {
        opts.kernel_list = k
            .split(',')
            .map(|x| KernelKind::parse(x.trim()))
            .collect::<anyhow::Result<Vec<_>>>()?;
    }
    opts.clients = a.get_usize("clients", opts.clients)?;
    opts.requests_per_client = a.get_usize("requests", opts.requests_per_client)?;
    opts.doc_len = a.get_usize("doc-len", opts.doc_len)?;
    if let Some(c) = a.get("conns-list") {
        opts.conns_list = parse_usize_list(c, "conns-list")?;
    }
    if let Some(b) = a.get("backend-list") {
        opts.backend_list = b
            .split(',')
            .map(|x| ServeBackend::parse(x.trim()))
            .collect::<anyhow::Result<Vec<_>>>()?;
    }
    opts.seed = cfg.seed;
    if let Some(j) = a.get("json") {
        opts.out_json = PathBuf::from(j);
    }
    run_bench(&cfg, &opts)?;
    Ok(0)
}

/// Dispatch. Returns the exit code.
pub fn dispatch(args: Args) -> anyhow::Result<i32> {
    match args.command.as_deref() {
        Some("gen-data") => cmd_gen_data(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("run") => cmd_run(&args),
        Some("arena") => cmd_arena(&args),
        Some("train-shard") => cmd_train_shard(&args),
        Some("combine") => cmd_combine(&args),
        Some("train") => cmd_train(&args),
        Some("predict") => cmd_predict(&args),
        Some("top-words") => cmd_top_words(&args),
        Some("serve") => cmd_serve(&args),
        Some("serve-bench") => cmd_serve_bench(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("figs") => cmd_figs(&args),
        Some("help") | None => {
            println!("{HELP}");
            Ok(0)
        }
        Some(other) => {
            eprintln!("unknown command '{other}'\n{HELP}");
            Ok(2)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    fn tmp(name: &str) -> String {
        let mut p = std::env::temp_dir();
        p.push(format!("cfslda_cli_{}_{name}", std::process::id()));
        p.to_str().unwrap().to_string()
    }

    #[test]
    fn gen_inspect_run_roundtrip() {
        let bow = tmp("cli.bow");
        let metrics = tmp("cli.json");
        let rc = cmd_gen_data(&parse(&format!(
            "gen-data --out {bow} --preset small --docs 160 --seed 5"
        )))
        .unwrap();
        assert_eq!(rc, 0);
        assert_eq!(cmd_inspect(&parse(&format!("inspect --data {bow} --bins 10"))).unwrap(), 0);
        let rc = cmd_run(&parse(&format!(
            "run --data {bow} --algorithm simple --train 120 --engine native --json {metrics}"
        )))
        .unwrap();
        assert_eq!(rc, 0);
        let v = json::parse(&std::fs::read_to_string(&metrics).unwrap()).unwrap();
        assert_eq!(v.get("algorithm").unwrap().as_str(), Some("simple-average"));
        assert!(v.get("mse").unwrap().as_f64().unwrap().is_finite());
        std::fs::remove_file(bow).ok();
        std::fs::remove_file(metrics).ok();
    }

    #[test]
    fn train_predict_topwords_workflow() {
        let bow = tmp("wf.bow");
        let model = tmp("wf.model");
        let preds = tmp("wf_preds.json");
        cmd_gen_data(&parse(&format!(
            "gen-data --out {bow} --preset small --docs 150 --seed 9"
        )))
        .unwrap();
        let rc = cmd_train(&parse(&format!(
            "train --data {bow} --out {model} --engine native --seed 9"
        )))
        .unwrap();
        assert_eq!(rc, 0);
        let rc = cmd_predict(&parse(&format!(
            "predict --model {model} --data {bow} --engine native --json {preds}"
        )))
        .unwrap();
        assert_eq!(rc, 0);
        let v = json::parse(&std::fs::read_to_string(&preds).unwrap()).unwrap();
        assert_eq!(v.get("yhat").unwrap().as_array().unwrap().len(), 150);
        assert_eq!(cmd_top_words(&parse(&format!("top-words --model {model} --k 3"))).unwrap(), 0);
        for f in [bow, model, preds] {
            std::fs::remove_file(f).ok();
        }
    }

    #[test]
    fn train_with_alias_mh_kernel_flags() {
        let bow = tmp("mh.bow");
        let model = tmp("mh.model");
        cmd_gen_data(&parse(&format!("gen-data --out {bow} --preset small --docs 120 --seed 8")))
            .unwrap();
        let rc = cmd_train(&parse(&format!(
            "train --data {bow} --out {model} --engine native --seed 8 \
             --kernel alias --resp-mode mh"
        )))
        .unwrap();
        assert_eq!(rc, 0);
        // the supervised-MH-trained model predicts like any other
        let rc = cmd_predict(&parse(&format!(
            "predict --model {model} --data {bow} --engine native --seed 8"
        )))
        .unwrap();
        assert_eq!(rc, 0);
        // validation wiring: mh on the dense kernel must be rejected
        assert!(cmd_train(&parse(&format!(
            "train --data {bow} --out {model} --engine native --kernel dense --resp-mode mh"
        )))
        .is_err());
        for f in [bow, model] {
            std::fs::remove_file(f).ok();
        }
    }

    #[test]
    fn predict_jobs_flag_is_deterministic_across_worker_counts() {
        let bow = tmp("jobs.bow");
        let model = tmp("jobs.model");
        let p1 = tmp("jobs1.json");
        let p3 = tmp("jobs3.json");
        cmd_gen_data(&parse(&format!("gen-data --out {bow} --preset small --docs 120 --seed 4")))
            .unwrap();
        cmd_train(&parse(&format!("train --data {bow} --out {model} --engine native --seed 4")))
            .unwrap();
        cmd_predict(&parse(&format!(
            "predict --model {model} --data {bow} --engine native --seed 11 --jobs 1 --json {p1}"
        )))
        .unwrap();
        cmd_predict(&parse(&format!(
            "predict --model {model} --data {bow} --engine native --seed 11 --jobs 3 --json {p3}"
        )))
        .unwrap();
        let v1 = json::parse(&std::fs::read_to_string(&p1).unwrap()).unwrap();
        let v3 = json::parse(&std::fs::read_to_string(&p3).unwrap()).unwrap();
        assert_eq!(v1.get("yhat"), v3.get("yhat"));
        for f in [bow, model, p1, p3] {
            std::fs::remove_file(f).ok();
        }
    }

    #[test]
    fn train_checkpoint_interrupt_resume_byte_identical() {
        use std::sync::atomic::AtomicBool;
        let bow = tmp("ck.bow");
        let ref_model = tmp("ck_ref.model");
        let res_model = tmp("ck_res.model");
        let dir_a = tmp("ck_ref_dir");
        let dir_b = tmp("ck_res_dir");
        for d in [&dir_a, &dir_b] {
            std::fs::remove_dir_all(d).ok();
        }
        cmd_gen_data(&parse(&format!(
            "gen-data --out {bow} --preset small --docs 130 --seed 12"
        )))
        .unwrap();
        // A local stop flag for every leg: the process-global SIGTERM flag
        // is exercised by util::signal's own test and must not be shared.
        let go = AtomicBool::new(false);
        // Reference: same flags (the cadence is chain-defining), never
        // interrupted.
        let rc = cmd_train_with_stop(
            &parse(&format!(
                "train --data {bow} --out {ref_model} --engine native --seed 12 \
                 --checkpoint-every 40 --checkpoint-dir {dir_a}"
            )),
            Some(&go),
        )
        .unwrap();
        assert_eq!(rc, 0);
        // Interrupted: stop flag raised before the run starts, so it exits
        // cleanly at the first boundary with a committed generation.
        let stop = AtomicBool::new(true);
        let rc = cmd_train_with_stop(
            &parse(&format!(
                "train --data {bow} --out {res_model} --engine native --seed 12 \
                 --checkpoint-every 40 --checkpoint-dir {dir_b}"
            )),
            Some(&stop),
        )
        .unwrap();
        assert_eq!(rc, 0);
        assert!(
            !Path::new(&res_model).exists(),
            "an interrupted run must not write a model"
        );
        // Resume to completion: the saved model must be byte-identical.
        let rc = cmd_train_with_stop(
            &parse(&format!(
                "train --data {bow} --out {res_model} --engine native --seed 12 \
                 --checkpoint-every 40 --resume {dir_b}"
            )),
            Some(&go),
        )
        .unwrap();
        assert_eq!(rc, 0);
        assert_eq!(
            std::fs::read(&ref_model).unwrap(),
            std::fs::read(&res_model).unwrap(),
            "resumed model must be byte-identical to the uninterrupted one"
        );
        // Flag validation: conflicting directories, missing cadence, and a
        // chain-config mismatch (different cadence -> fingerprint error).
        let err = cmd_train(&parse(&format!(
            "train --data {bow} --out {res_model} --engine native --seed 12 \
             --checkpoint-every 40 --checkpoint-dir {dir_a} --resume {dir_b}"
        )))
        .unwrap_err()
        .to_string();
        assert!(err.contains("conflicts"), "{err}");
        let err = cmd_train(&parse(&format!(
            "train --data {bow} --out {res_model} --engine native --seed 12 \
             --resume {dir_b}"
        )))
        .unwrap_err()
        .to_string();
        assert!(err.contains("--checkpoint-every"), "{err}");
        let err = cmd_train(&parse(&format!(
            "train --data {bow} --out {res_model} --engine native --seed 12 \
             --checkpoint-every 25 --resume {dir_b}"
        )))
        .unwrap_err()
        .to_string();
        assert!(err.contains("fingerprint"), "{err}");
        for f in [bow, ref_model, res_model] {
            std::fs::remove_file(f).ok();
        }
        for d in [dir_a, dir_b] {
            std::fs::remove_dir_all(d).ok();
        }
    }

    #[test]
    fn run_checkpoint_interrupt_resume_matches_uninterrupted() {
        use std::sync::atomic::AtomicBool;
        let bow = tmp("rck.bow");
        let cfgf = tmp("rck_cfg.json");
        let j_ref = tmp("rck_ref.json");
        let j_res = tmp("rck_res.json");
        let dir_ref = tmp("rck_ref_dir");
        let dir = tmp("rck_dir");
        for d in [&dir_ref, &dir] {
            std::fs::remove_dir_all(d).ok();
        }
        cmd_gen_data(&parse(&format!(
            "gen-data --out {bow} --preset small --docs 140 --seed 13"
        )))
        .unwrap();
        // Cadence via the config file (the `[train] checkpoint_every`
        // knob); boundaries at sweeps 5 and 10 of 12.
        std::fs::write(
            &cfgf,
            r#"{"train": {"sweeps": 12, "burnin": 3, "eta_every": 3, "checkpoint_every": 5}}"#,
        )
        .unwrap();
        let flags = format!(
            "--data {bow} --algorithm simple --train 100 --engine native --seed 13 \
             --config {cfgf}"
        );
        // Local stop flags only: the process-global SIGTERM flag belongs to
        // util::signal's test.
        let go = AtomicBool::new(false);
        cmd_run_with_stop(
            &parse(&format!(
                "run {flags} --checkpoint-dir {dir_ref} --json {j_ref}"
            )),
            Some(&go),
        )
        .unwrap();
        let stop = AtomicBool::new(true);
        let rc = cmd_run_with_stop(
            &parse(&format!("run {flags} --checkpoint-dir {dir}")),
            Some(&stop),
        )
        .unwrap();
        assert_eq!(rc, 0);
        cmd_run_with_stop(
            &parse(&format!("run {flags} --resume {dir} --json {j_res}")),
            Some(&go),
        )
        .unwrap();
        let vr = json::parse(&std::fs::read_to_string(&j_ref).unwrap()).unwrap();
        let vs = json::parse(&std::fs::read_to_string(&j_res).unwrap()).unwrap();
        for k in ["mse", "acc", "r2"] {
            assert_eq!(
                vr.get(k).unwrap().as_f64().unwrap().to_bits(),
                vs.get(k).unwrap().as_f64().unwrap().to_bits(),
                "{k} must match the uninterrupted run bit-for-bit"
            );
        }
        for f in [bow, cfgf, j_ref, j_res] {
            std::fs::remove_file(f).ok();
        }
        for d in [dir_ref, dir] {
            std::fs::remove_dir_all(d).ok();
        }
    }

    /// The tentpole's CLI acceptance path: `arena pack` + `train-shard`×M
    /// (separate invocations, as separate OS processes would run them) +
    /// `combine` must reproduce `cfslda run` bit-for-bit — same yhat bits,
    /// same metrics — with zero setup bytes copied per shard.
    #[test]
    fn arena_pack_train_shard_combine_matches_run() {
        let bow = tmp("mp.bow");
        let arena = tmp("mp.arena");
        let cfgf = tmp("mp_cfg.json");
        let dir = tmp("mp_shards");
        let j_run = tmp("mp_run.json");
        let j_comb = tmp("mp_comb.json");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        cmd_gen_data(&parse(&format!(
            "gen-data --out {bow} --preset small --docs 64 --seed 21"
        )))
        .unwrap();
        // `run` partitions into [parallel] shards; train-shard's M must
        // match it for byte identity.
        std::fs::write(
            &cfgf,
            r#"{"train": {"sweeps": 10, "burnin": 2, "eta_every": 2},
                "parallel": {"shards": 3, "threads": 2}}"#,
        )
        .unwrap();
        cmd_run(&parse(&format!(
            "run --data {bow} --algorithm weighted --train 48 --engine native \
             --seed 21 --config {cfgf} --json {j_run}"
        )))
        .unwrap();
        assert_eq!(
            dispatch(parse(&format!("arena pack --input {bow} --out {arena}"))).unwrap(),
            0
        );
        for j in 0..3 {
            let j_shard = tmp(&format!("mp_shard{j}.json"));
            let rc = cmd_train_shard(&parse(&format!(
                "train-shard --arena {arena} --shard {j}/3 --algorithm weighted \
                 --train 48 --engine native --seed 21 --config {cfgf} \
                 --out-dir {dir} --json {j_shard}"
            )))
            .unwrap();
            assert_eq!(rc, 0);
            let v = json::parse(&std::fs::read_to_string(&j_shard).unwrap()).unwrap();
            assert_eq!(v.get("setup_copied_bytes").unwrap().as_f64(), Some(0.0));
            assert!(v.get("setup_referenced_bytes").unwrap().as_f64().unwrap() > 0.0);
            assert_eq!(v.get("sampling_syncs").unwrap().as_f64(), Some(0.0));
            std::fs::remove_file(j_shard).ok();
        }
        let rc = cmd_combine(&parse(&format!(
            "combine --dir {dir} --engine native --json {j_comb}"
        )))
        .unwrap();
        assert_eq!(rc, 0);
        let vr = json::parse(&std::fs::read_to_string(&j_run).unwrap()).unwrap();
        let vc = json::parse(&std::fs::read_to_string(&j_comb).unwrap()).unwrap();
        assert_eq!(
            vr.get("yhat"),
            vc.get("yhat"),
            "multi-process yhat must match the in-process run exactly"
        );
        for k in ["mse", "acc", "r2"] {
            assert_eq!(vs_bits(&vr, k), vs_bits(&vc, k), "{k} must match bit-for-bit");
        }
        assert_eq!(vc.get("algorithm").unwrap().as_str(), Some("weighted-average"));
        assert_eq!(vc.get("weights").unwrap().as_array().unwrap().len(), 3);
        for f in [bow, arena, cfgf, j_run, j_comb] {
            std::fs::remove_file(f).ok();
        }
        std::fs::remove_dir_all(dir).ok();
    }

    fn vs_bits(v: &Value, k: &str) -> u64 {
        v.get(k).unwrap().as_f64().unwrap().to_bits()
    }

    /// A shard process interrupted at a checkpoint boundary and resumed
    /// must persist a byte-identical artifact (the CLI leg of the
    /// multi-process crash-recovery story; CI additionally kills a real
    /// process with SIGKILL).
    #[test]
    fn train_shard_interrupt_resume_byte_identical() {
        use std::sync::atomic::AtomicBool;
        let bow = tmp("tsk.bow");
        let arena = tmp("tsk.arena");
        let cfgf = tmp("tsk_cfg.json");
        let ref_art = tmp("tsk_ref.shrd");
        let res_art = tmp("tsk_res.shrd");
        let dir_a = tmp("tsk_ref_dir");
        let dir_b = tmp("tsk_res_dir");
        for d in [&dir_a, &dir_b] {
            std::fs::remove_dir_all(d).ok();
        }
        cmd_gen_data(&parse(&format!(
            "gen-data --out {bow} --preset small --docs 60 --seed 31"
        )))
        .unwrap();
        std::fs::write(
            &cfgf,
            r#"{"train": {"sweeps": 10, "burnin": 2, "eta_every": 2},
                "parallel": {"shards": 2, "threads": 1}}"#,
        )
        .unwrap();
        let flags = format!(
            "train-shard --arena {arena} --shard 1/2 --algorithm simple --train 44 \
             --engine native --seed 31 --config {cfgf} --checkpoint-every 4"
        );
        assert_eq!(
            dispatch(parse(&format!("arena pack --input {bow} --out {arena}"))).unwrap(),
            0
        );
        // Reference: same cadence (it is chain-defining), never stopped.
        let go = AtomicBool::new(false);
        let rc = cmd_train_shard_with_stop(
            &parse(&format!("{flags} --checkpoint-dir {dir_a} --out {ref_art}")),
            Some(&go),
        )
        .unwrap();
        assert_eq!(rc, 0);
        // Interrupted at the first boundary: no artifact yet.
        let stop = AtomicBool::new(true);
        let rc = cmd_train_shard_with_stop(
            &parse(&format!("{flags} --checkpoint-dir {dir_b} --out {res_art}")),
            Some(&stop),
        )
        .unwrap();
        assert_eq!(rc, 0);
        assert!(
            !Path::new(&res_art).exists(),
            "an interrupted shard must not write its artifact"
        );
        // Resume to completion: artifact bytes must match the reference.
        let rc = cmd_train_shard_with_stop(
            &parse(&format!("{flags} --resume {dir_b} --out {res_art}")),
            Some(&go),
        )
        .unwrap();
        assert_eq!(rc, 0);
        assert_eq!(
            std::fs::read(&ref_art).unwrap(),
            std::fs::read(&res_art).unwrap(),
            "resumed shard artifact must be byte-identical"
        );
        for f in [bow, arena, cfgf, ref_art, res_art] {
            std::fs::remove_file(f).ok();
        }
        for d in [dir_a, dir_b] {
            std::fs::remove_dir_all(d).ok();
        }
    }

    #[test]
    fn arena_subcommand_validation() {
        assert!(cmd_arena(&parse("arena")).is_err());
        assert!(cmd_arena(&parse("arena unpack")).is_err());
        assert!(cmd_arena(&parse("arena pack --out x")).is_err());
    }

    #[test]
    fn train_from_text_jsonl_persists_vocab() {
        let jsonl = tmp("text.jsonl");
        let model = tmp("text.model");
        let mut lines = String::new();
        // enough repetition that every doc survives df pruning
        for i in 0..24 {
            let (text, y) = if i % 2 == 0 {
                ("strong revenue growth and confident operational outlook ahead", 1.0)
            } else {
                ("weak revenue decline with operational risk and cautious outlook", -1.0)
            };
            lines.push_str(&format!("{{\"text\": \"{text} case{i}\", \"response\": {y}}}\n"));
        }
        std::fs::write(&jsonl, lines).unwrap();
        let rc = cmd_train(&parse(&format!(
            "train --data {jsonl} --out {model} --engine native --seed 3"
        )))
        .unwrap();
        assert_eq!(rc, 0);
        let (m, vocab) = load_model_full(Path::new(&model)).unwrap();
        let vocab = vocab.expect("jsonl training should persist the vocabulary");
        assert_eq!(vocab.len(), m.w);
        assert!(vocab.id("revenue").is_some());
        // top-words renders with the vocabulary present
        assert_eq!(cmd_top_words(&parse(&format!("top-words --model {model} --k 3"))).unwrap(), 0);
        for f in [jsonl, model] {
            std::fs::remove_file(f).ok();
        }
    }

    #[test]
    fn serve_bench_smoke_emits_json() {
        let bow = tmp("sb.bow");
        let model = tmp("sb.model");
        let out = tmp("sb_bench.json");
        cmd_gen_data(&parse(&format!("gen-data --out {bow} --preset small --docs 100 --seed 6")))
            .unwrap();
        cmd_train(&parse(&format!("train --data {bow} --out {model} --engine native --seed 6")))
            .unwrap();
        let rc = cmd_serve_bench(&parse(&format!(
            "serve-bench --model {model} --workers-list 1,2 --batch-list 2 --clients 2 \
             --requests 3 --doc-len 12 --conns-list 4 --backend-list threads,epoll \
             --json {out}"
        )))
        .unwrap();
        assert_eq!(rc, 0);
        let v = json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(v.get("bench").unwrap().as_str(), Some("serve"));
        let cells = v.get("results").unwrap().as_array().unwrap();
        // default kernel sweep (sparse, alias) x workers-list (1, 2)
        assert_eq!(cells.len(), 4);
        for c in cells {
            assert!(c.get("docs_per_sec").unwrap().as_f64().unwrap() > 0.0);
            assert!(c.get("p95_ms").unwrap().as_f64().unwrap().is_finite());
            // sourced from the server's own latency histogram
            assert!(c.get("server_p95_ms").unwrap().as_f64().unwrap() > 0.0);
        }
        let kernels: Vec<&str> =
            cells.iter().filter_map(|c| c.get("kernel").unwrap().as_str()).collect();
        assert_eq!(kernels, vec!["sparse", "sparse", "alias", "alias"]);
        // connection-scaling sweep: one cell per backend at 4 conns
        let conns = v.get("conns").unwrap().as_array().unwrap();
        assert_eq!(conns.len(), 2);
        let backends: Vec<&str> =
            conns.iter().filter_map(|c| c.get("backend").unwrap().as_str()).collect();
        assert_eq!(backends, vec!["threads", "epoll"]);
        for c in conns {
            assert_eq!(c.get("conns").unwrap().as_usize(), Some(4));
            assert_eq!(c.get("connected").unwrap().as_usize(), Some(4));
            assert!(c.get("requests").unwrap().as_usize().unwrap() > 0);
            for k in ["p50_ms", "p95_ms", "p99_ms", "shed_rate"] {
                assert!(c.get(k).unwrap().as_f64().unwrap().is_finite(), "{k}");
            }
            assert_eq!(c.get("shed").unwrap().as_usize(), Some(0));
        }
        for f in [bow, model, out] {
            std::fs::remove_file(f).ok();
        }
    }

    #[test]
    fn dispatch_help_and_unknown() {
        assert_eq!(dispatch(parse("help")).unwrap(), 0);
        assert_eq!(dispatch(Args::default()).unwrap(), 0);
        assert_eq!(dispatch(parse("bogus")).unwrap(), 2);
    }

    #[test]
    fn figs_validation() {
        assert!(cmd_figs(&parse("figs --fig 9")).is_err());
    }
}
