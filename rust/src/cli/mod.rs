//! Command-line interface (hand-rolled: clap is not in the offline vendor
//! set). `cfslda <command> [--flag value ...]`; see `cfslda help`.

pub mod args;
pub mod commands;
