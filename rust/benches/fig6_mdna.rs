//! Bench: regenerates paper Fig 6 — computation time + test MSE for the
//! four algorithms on the Experiment-I (MD&A -> EPS) workload.
//!
//! Full scale: `cargo bench --bench fig6_mdna` (4216 docs, 100 sweeps,
//! 3 repeats). CI scale: append `-- --quick`.

use cfslda::bench_harness::quick_mode;
use cfslda::config::schema::EngineKind;
use cfslda::experiments::runner::{check_fig_shape, render_table, run_comparison, Comparison};
use cfslda::runtime::EngineHandle;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    cfslda::util::logging::init();
    let quick = quick_mode();
    let (scale, runs, sweeps) = if quick { (0.1, 1, 20) } else { (1.0, 3, 100) };
    let mut c = Comparison::fig6(scale, runs);
    c.cfg.train.sweeps = sweeps;
    c.cfg.train.burnin = (sweeps / 10).max(2);
    c.cfg.train.eta_every = 5;
    let dir = std::env::var("CFSLDA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let engine = EngineHandle::from_kind(EngineKind::Auto, Path::new(&dir))?;
    eprintln!(
        "fig6 bench: docs={} vocab={} sweeps={} runs={} engine={} (quick={quick})",
        c.spec.docs, c.spec.vocab, sweeps, runs, engine.name()
    );
    let (series, _) = run_comparison(&c, &engine)?;
    println!("{}", render_table("Fig 6: MD&A -> EPS, four algorithms", &series, false));
    match check_fig_shape(&series, false) {
        Ok(()) => println!("fig6 shape: PASS (naive worst quality; simple fast+accurate; weighted slowest parallel arm)"),
        Err(e) => println!("fig6 shape: MARGINAL at this scale — {e}"),
    }
    Ok(())
}
