//! Bench: the L3 hot path — collapsed-Gibbs token updates per second,
//! reported **per kernel** (dense vs sparse vs alias-MH; DESIGN.md §Perf).
//!
//! The paper's wall-time claims all reduce to this number times token
//! count. Three regimes:
//!
//! * `train-lda`  — eta-inactive training sweeps (plain-LDA conditional):
//!   kernel-specific; the sparse kernel's bucket decomposition and the
//!   alias kernel's O(1) MH proposals apply.
//! * `predict`    — frozen-phi inference (paper eq. 4): fully kernel-
//!   specific; the sparse path is O(nnz(N_d)) per token and the alias
//!   path amortized O(1) (the serving regime).
//! * `train-slda` — eta-active sweeps (Gaussian margin): kernel-specific
//!   since the supervised MH decomposition (DESIGN.md §Perf) — dense runs
//!   the exact O(T) conditional, sparse/alias their own proposals with the
//!   O(1) response-ratio correction (`resp_mode = auto`); MH acceptance
//!   rates are reported alongside tokens/s.
//!
//! A fourth regime tracks the token-arena refactor (DESIGN.md §Memory
//! layout):
//!
//! * `shard-setup` — partitioning the training corpus into M ∈ {1, 4, 16}
//!   shards, **arena** (zero-copy `CorpusView`s) vs **baseline** (the
//!   legacy deep-copy `select` path), with copied/referenced byte
//!   accounting, plus end-to-end shard training tokens/s on each layout.
//! * `arena-io`   — the out-of-core `CFSARENA1` path (DESIGN.md
//!   §Out-of-core): streaming pack time, cold/warm `mmap` open+validate
//!   time, and training tokens/s through the mapping vs the heap corpus
//!   (the mmap-tax check: the two must be indistinguishable once pages
//!   are resident).
//!
//! Emits `BENCH_gibbs_hotpath.json` at the repo root (tokens/sec per kernel
//! per T ∈ {16, 64, 256, 1024}, kernel-over-kernel speedups, and the
//! shard-setup table) so the perf trajectory is tracked across PRs.

use cfslda::bench_harness::{bench, bench_throughput, quick_mode, render_table, BenchResult};
use cfslda::config::json::{self, Value};
use cfslda::config::schema::{EngineKind, ExperimentConfig, KernelKind};
use cfslda::data::arena_file::{write_arena, ArenaMap};
use cfslda::data::partition::{random_shards, shard_corpora, shard_views};
use cfslda::data::synthetic::{generate_corpus, SyntheticSpec};
use cfslda::parallel::comm::view_setup_bytes;
use cfslda::runtime::EngineHandle;
use cfslda::sampler::gibbs_predict::infer_zbar_with_kernel;
use cfslda::sampler::gibbs_train::train;
use cfslda::util::rng::Pcg64;
use std::hint::black_box;
use std::path::Path;

struct Record {
    t: usize,
    kernel: &'static str,
    path: &'static str,
    tokens_per_sec: f64,
    median_secs: f64,
    /// Supervised-MH acceptance rate (train-slda on sparse/alias only).
    mh_accept_rate: Option<f64>,
    /// Alias-table rebuilds per 1k tokens over one run (alias kernel only)
    /// — tracks how well the staleness budget amortizes table construction.
    alias_rebuilds_per_1k_tokens: Option<f64>,
}

fn push(
    records: &mut Vec<Record>,
    t: usize,
    kernel: &'static str,
    path: &'static str,
    r: &BenchResult,
    mh_accept_rate: Option<f64>,
    alias_rebuilds_per_1k_tokens: Option<f64>,
) {
    records.push(Record {
        t,
        kernel,
        path,
        tokens_per_sec: r.throughput().unwrap_or(0.0),
        median_secs: r.median(),
        mh_accept_rate,
        alias_rebuilds_per_1k_tokens,
    });
}

/// Normalize one run's `(alias_rebuilds, tokens_sampled)` to rebuilds per
/// 1k token updates; `None` for kernels without alias tables.
fn rebuilds_per_1k((rebuilds, tokens_sampled): (u64, u64)) -> Option<f64> {
    (rebuilds > 0 && tokens_sampled > 0)
        .then(|| rebuilds as f64 * 1000.0 / tokens_sampled as f64)
}

fn main() -> anyhow::Result<()> {
    cfslda::util::logging::init();
    let quick = quick_mode();
    let mut spec = SyntheticSpec::mdna();
    spec.docs = if quick { 200 } else { 800 };
    spec.vocab = if quick { 500 } else { 2000 };
    let mut rng = Pcg64::seed_from_u64(20170710);
    let corpus = generate_corpus(&spec, &mut rng);
    let tokens = corpus.num_tokens() as f64;
    let engine = EngineHandle::native();
    let iters = if quick { 2 } else { 3 };

    let mut results = Vec::new();
    let mut records: Vec<Record> = Vec::new();

    for &t in &[16usize, 64, 256, 1024] {
        // Base config: burn-in-only training => eta stays zero => the
        // plain-LDA conditional runs for every sweep.
        let mut base = ExperimentConfig::quick();
        base.engine = EngineKind::Native;
        base.model.topics = t;
        base.train.sweeps = 3;
        base.train.burnin = 2;
        base.train.eta_every = 100; // never fires before the final solve
        base.train.predict_sweeps = 8;
        base.train.predict_burnin = 2;

        // One frozen model per T for the prediction benches (cheap 2-sweep
        // train; phi depends only on counts).
        let model = {
            let mut cfg = base.clone();
            cfg.train.sweeps = 2;
            cfg.train.burnin = 1;
            let mut r = Pcg64::seed_from_u64(7);
            train(&corpus, &cfg, &engine, &mut r)?.model
        };

        for &kernel in &[KernelKind::Dense, KernelKind::Sparse, KernelKind::Alias] {
            let kname = kernel.name();

            let mut cfg = base.clone();
            cfg.sampler.kernel = kernel;
            let mut seed = t as u64 * 1000;
            let mut rebuilds = (0u64, 0u64); // (rebuilds, tokens_sampled)
            let r = bench_throughput(
                &format!("gibbs/train-lda {kname} T={t}"),
                0,
                iters,
                tokens * cfg.train.sweeps as f64,
                || {
                    seed += 1;
                    let mut r = Pcg64::seed_from_u64(seed);
                    let out = train(&corpus, &cfg, &engine, &mut r).unwrap();
                    rebuilds = (out.alias_rebuilds, out.tokens_sampled);
                },
            );
            push(&mut records, t, kname, "train_lda", &r, None, rebuilds_per_1k(rebuilds));
            results.push(r);

            let mut seed = t as u64 * 2000;
            let r = bench_throughput(
                &format!("gibbs/predict {kname} T={t}"),
                0,
                iters,
                tokens * base.train.predict_sweeps as f64,
                || {
                    seed += 1;
                    let mut r = Pcg64::seed_from_u64(seed);
                    infer_zbar_with_kernel(&model, &corpus, &base.train, kernel, &mut r);
                },
            );
            push(&mut records, t, kname, "predict", &r, None, None);
            results.push(r);

            // Supervised (eta-active) sweeps, per kernel: resp_mode = auto
            // gives dense the exact conditional and sparse/alias their MH
            // decomposition (DESIGN.md §Perf). Acceptance rates ride along.
            let mut cfg2 = base.clone();
            cfg2.sampler.kernel = kernel;
            cfg2.train.sweeps = 4;
            cfg2.train.burnin = 1;
            cfg2.train.eta_every = 1;
            let mut seed = t as u64 * 3000;
            let mut mh = (0u64, 0u64);
            let mut rebuilds = (0u64, 0u64);
            let r = bench_throughput(
                &format!("gibbs/train-slda {kname} T={t}"),
                0,
                iters,
                tokens * cfg2.train.sweeps as f64,
                || {
                    seed += 1;
                    let mut r = Pcg64::seed_from_u64(seed);
                    let out = train(&corpus, &cfg2, &engine, &mut r).unwrap();
                    mh = (out.resp_proposed, out.resp_accepted);
                    rebuilds = (out.alias_rebuilds, out.tokens_sampled);
                },
            );
            let accept = if mh.0 > 0 {
                Some(mh.1 as f64 / mh.0 as f64)
            } else {
                None
            };
            if let Some(a) = accept {
                println!("train-slda {kname} T={t}: MH acceptance {:.1}%", a * 100.0);
            }
            push(&mut records, t, kname, "train_slda", &r, accept, rebuilds_per_1k(rebuilds));
            results.push(r);
        }
    }

    // === Shard setup: arena views vs deep-copy baseline at M ∈ {1, 4, 16}.
    // Setup cost is what the paper's communication-free design pays up
    // front; the arena path must make it index-sized, not token-sized.
    let mut shard_entries: Vec<Value> = Vec::new();
    {
        let mut cfg = ExperimentConfig::quick();
        cfg.engine = EngineKind::Native;
        cfg.model.topics = 16;
        cfg.train.sweeps = 2;
        cfg.train.burnin = 2;
        cfg.train.eta_every = 100;
        let setup_iters = if quick { 20 } else { 50 };
        for &m in &[1usize, 4, 16] {
            let shards = random_shards(corpus.num_docs(), m, &mut Pcg64::seed_from_u64(m as u64));

            let r_arena = bench(&format!("shard-setup/arena M={m}"), 1, setup_iters, || {
                black_box(shard_views(&corpus, &shards));
            });
            let (copied, referenced) = shards
                .iter()
                .map(|s| view_setup_bytes(&corpus.view_of(s)))
                .fold((0u64, 0u64), |(c, r), (dc, dr)| (c + dc, r + dr));

            let r_copy = bench(&format!("shard-setup/baseline M={m}"), 1, setup_iters, || {
                black_box(shard_corpora(&corpus, &shards));
            });
            // The baseline physically duplicates every shard's wire image —
            // exactly the bytes the arena path only references.
            let copy_bytes: u64 = referenced;

            // End-to-end shard training on each layout (tokens/s over all M
            // shards, sequential — layout is the only variable).
            let views = shard_views(&corpus, &shards);
            let subs = shard_corpora(&corpus, &shards);
            let train_work = tokens * cfg.train.sweeps as f64;
            let r_train_arena = bench_throughput(
                &format!("shard-train/arena M={m}"),
                0,
                iters,
                train_work,
                || {
                    for (i, v) in views.iter().enumerate() {
                        let mut r = Pcg64::seed_from_u64(1000 + i as u64);
                        train(*v, &cfg, &engine, &mut r).unwrap();
                    }
                },
            );
            let r_train_copy = bench_throughput(
                &format!("shard-train/baseline M={m}"),
                0,
                iters,
                train_work,
                || {
                    for (i, s) in subs.iter().enumerate() {
                        let mut r = Pcg64::seed_from_u64(1000 + i as u64);
                        train(s, &cfg, &engine, &mut r).unwrap();
                    }
                },
            );

            for (layout, setup, tr, cb, rb) in [
                ("arena", &r_arena, &r_train_arena, copied, referenced),
                ("baseline", &r_copy, &r_train_copy, copy_bytes, 0u64),
            ] {
                shard_entries.push(Value::object(vec![
                    ("m", Value::Number(m as f64)),
                    ("layout", Value::String(layout.to_string())),
                    ("setup_secs", Value::Number(setup.median())),
                    ("copied_bytes", Value::Number(cb as f64)),
                    ("referenced_bytes", Value::Number(rb as f64)),
                    (
                        "train_tokens_per_sec",
                        Value::Number(tr.throughput().unwrap_or(0.0)),
                    ),
                ]));
            }
            println!(
                "shard-setup M={m}: arena {:.2}us ({copied}B copied, {referenced}B by ref) \
                 vs baseline {:.2}us ({copy_bytes}B copied)",
                r_arena.median() * 1e6,
                r_copy.median() * 1e6,
            );
            results.push(r_arena);
            results.push(r_copy);
            results.push(r_train_arena);
            results.push(r_train_copy);
        }
    }

    // === Arena I/O: pack + mmap open + train-through-the-mapping.
    let mut arena_entries: Vec<Value> = Vec::new();
    {
        let mut cfg = ExperimentConfig::quick();
        cfg.engine = EngineKind::Native;
        cfg.model.topics = 16;
        cfg.train.sweeps = 2;
        cfg.train.burnin = 2;
        cfg.train.eta_every = 100;
        let path = std::env::temp_dir().join(format!("cfslda_bench_{}.arena", std::process::id()));
        let io_iters = if quick { 5 } else { 20 };

        let r_pack = bench("arena-io/pack", 1, io_iters, || {
            write_arena(&corpus, &path).unwrap();
        });
        let file_bytes = std::fs::metadata(&path)?.len();

        // "Cold" = the first open after the pack above (the page cache is
        // as unfriendly as a userspace bench can make it); "warm" = the
        // steady state. Both include the full checksum validation pass —
        // the dominant open cost by design (hostile-input contract).
        let r_open_cold = bench("arena-io/open-cold", 0, 1, || {
            black_box(ArenaMap::open(&path).unwrap().num_tokens());
        });
        let r_open_warm = bench("arena-io/open-warm", 1, io_iters, || {
            black_box(ArenaMap::open(&path).unwrap().num_tokens());
        });

        let map = ArenaMap::open(&path)?;
        let train_work = tokens * cfg.train.sweeps as f64;
        let r_train_mmap = bench_throughput("arena-io/train mmap", 0, iters, train_work, || {
            let mut r = Pcg64::seed_from_u64(4242);
            train(map.view(), &cfg, &engine, &mut r).unwrap();
        });
        let r_train_heap = bench_throughput("arena-io/train heap", 0, iters, train_work, || {
            let mut r = Pcg64::seed_from_u64(4242);
            train(&corpus, &cfg, &engine, &mut r).unwrap();
        });
        arena_entries.push(Value::object(vec![
            ("file_bytes", Value::Number(file_bytes as f64)),
            ("pack_secs", Value::Number(r_pack.median())),
            ("open_cold_secs", Value::Number(r_open_cold.median())),
            ("open_warm_secs", Value::Number(r_open_warm.median())),
            (
                "train_mmap_tokens_per_sec",
                Value::Number(r_train_mmap.throughput().unwrap_or(0.0)),
            ),
            (
                "train_heap_tokens_per_sec",
                Value::Number(r_train_heap.throughput().unwrap_or(0.0)),
            ),
        ]));
        println!(
            "arena-io: {file_bytes}B packed in {:.2}ms, open cold {:.2}ms / warm {:.2}ms, \
             train mmap {:.0} tok/s vs heap {:.0} tok/s",
            r_pack.median() * 1e3,
            r_open_cold.median() * 1e3,
            r_open_warm.median() * 1e3,
            r_train_mmap.throughput().unwrap_or(0.0),
            r_train_heap.throughput().unwrap_or(0.0),
        );
        results.push(r_pack);
        results.push(r_open_cold);
        results.push(r_open_warm);
        results.push(r_train_mmap);
        results.push(r_train_heap);
        drop(map);
        std::fs::remove_file(&path).ok();
    }

    println!(
        "{}",
        render_table(
            &format!("Gibbs hot path (docs={} tokens={})", spec.docs, tokens as u64),
            &results
        )
    );

    // Kernel-over-kernel speedups per (T, path). The acceptance bars: alias
    // predict throughput above sparse at T >= 256, and the supervised
    // (train_slda) MH kernels above dense at T = 1024.
    let mut speedups: Vec<Value> = Vec::new();
    for &t in &[16usize, 64, 256, 1024] {
        for path in ["train_lda", "predict", "train_slda"] {
            let find = |kernel: &str| {
                records
                    .iter()
                    .find(|r| r.t == t && r.path == path && r.kernel == kernel)
                    .map(|r| r.tokens_per_sec)
            };
            if let (Some(d), Some(s), Some(a)) =
                (find("dense"), find("sparse"), find("alias"))
            {
                if d > 0.0 && s > 0.0 {
                    println!(
                        "speedup {path} T={t}: sparse/dense = {:.2}x, \
                         alias/dense = {:.2}x, alias/sparse = {:.2}x",
                        s / d,
                        a / d,
                        a / s
                    );
                    speedups.push(Value::object(vec![
                        ("t", Value::Number(t as f64)),
                        ("path", Value::String(path.to_string())),
                        ("sparse_over_dense", Value::Number(s / d)),
                        ("alias_over_dense", Value::Number(a / d)),
                        ("alias_over_sparse", Value::Number(a / s)),
                    ]));
                }
            }
        }
    }

    let entries: Vec<Value> = records
        .iter()
        .map(|r| {
            let mut fields = vec![
                ("t", Value::Number(r.t as f64)),
                ("kernel", Value::String(r.kernel.to_string())),
                ("path", Value::String(r.path.to_string())),
                ("tokens_per_sec", Value::Number(r.tokens_per_sec)),
                ("median_secs", Value::Number(r.median_secs)),
            ];
            if let Some(a) = r.mh_accept_rate {
                fields.push(("mh_accept_rate", Value::Number(a)));
            }
            if let Some(rb) = r.alias_rebuilds_per_1k_tokens {
                fields.push(("alias_rebuilds_per_1k_tokens", Value::Number(rb)));
            }
            Value::object(fields)
        })
        .collect();
    let doc = Value::object(vec![
        ("bench", Value::String("gibbs_hotpath".to_string())),
        ("quick", Value::Bool(quick)),
        ("docs", Value::Number(spec.docs as f64)),
        ("tokens", Value::Number(tokens)),
        ("results", Value::Array(entries)),
        ("speedups", Value::Array(speedups)),
        ("shard_setup", Value::Array(shard_entries)),
        ("arena_io", Value::Array(arena_entries)),
    ]);
    // Repo root sits one level above the cargo package (rust/); fall back
    // to the working directory when run from the root itself.
    let out = if Path::new("../ROADMAP.md").exists() {
        "../BENCH_gibbs_hotpath.json"
    } else {
        "BENCH_gibbs_hotpath.json"
    };
    std::fs::write(out, json::to_string_pretty(&doc))?;
    println!("wrote {out}");
    Ok(())
}
