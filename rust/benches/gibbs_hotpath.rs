//! Bench: the L3 hot path — collapsed-Gibbs token updates per second.
//!
//! This is the §Perf tracking bench (EXPERIMENTS.md): the paper's wall-time
//! claims all reduce to this number times token count. Reported for the
//! response-inactive regime (plain-LDA conditional, burn-in sweeps) and the
//! response-active regime (Gaussian margin with T exponentials per token).

use cfslda::bench_harness::{bench_throughput, quick_mode, render_table};
use cfslda::config::schema::{EngineKind, ExperimentConfig};
use cfslda::data::synthetic::{generate_corpus, SyntheticSpec};
use cfslda::runtime::EngineHandle;
use cfslda::sampler::gibbs_train::train;
use cfslda::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    cfslda::util::logging::init();
    let quick = quick_mode();
    let mut spec = SyntheticSpec::mdna();
    spec.docs = if quick { 400 } else { 1500 };
    spec.vocab = if quick { 500 } else { 2000 };
    let mut rng = Pcg64::seed_from_u64(20170710);
    let corpus = generate_corpus(&spec, &mut rng);
    let tokens = corpus.num_tokens() as f64;
    let engine = EngineHandle::native();
    let iters = if quick { 2 } else { 4 };

    let mut results = Vec::new();
    for t in [8usize, 16, 32, 64] {
        // response-inactive: burn-in only (eta stays zero => LDA conditional)
        let mut cfg = ExperimentConfig::quick();
        cfg.engine = EngineKind::Native;
        cfg.model.topics = t;
        cfg.train.sweeps = 3;
        cfg.train.burnin = 2;
        cfg.train.eta_every = 100; // never fires before the final solve
        let mut seed = 0u64;
        results.push(bench_throughput(
            &format!("gibbs/lda-conditional T={t}"),
            0,
            iters,
            tokens * cfg.train.sweeps as f64,
            || {
                seed += 1;
                let mut r = Pcg64::seed_from_u64(seed);
                train(&corpus, &cfg, &engine, &mut r).unwrap();
            },
        ));

        // response-active: eta solved after sweep 1, margin active after
        let mut cfg2 = cfg.clone();
        cfg2.train.sweeps = 4;
        cfg2.train.burnin = 1;
        cfg2.train.eta_every = 1;
        results.push(bench_throughput(
            &format!("gibbs/slda-conditional T={t}"),
            0,
            iters,
            tokens * cfg2.train.sweeps as f64,
            || {
                seed += 1;
                let mut r = Pcg64::seed_from_u64(seed);
                train(&corpus, &cfg2, &engine, &mut r).unwrap();
            },
        ));
    }
    println!(
        "{}",
        render_table(
            &format!("Gibbs hot path (docs={} tokens={})", spec.docs, tokens as u64),
            &results
        )
    );
    Ok(())
}
