//! Ablation bench: Weighted Average weight schemes (paper §III-C-d).
//!
//! Compares uniform (== Simple), inverse-train-MSE, and train-accuracy
//! weights under *heterogeneous shards*: we deliberately unbalance the
//! partition quality by giving one shard label noise, so weighting has
//! signal to exploit — the regime the paper designed Weighted Average for.

use cfslda::bench_harness::quick_mode;
use cfslda::combine::rules::combine_median;
use cfslda::combine::{combine_predictions, weights, CombineRule, WeightScheme};
use cfslda::config::schema::{EngineKind, ExperimentConfig};
use cfslda::data::corpus::Dataset;
use cfslda::data::partition::{random_shards, shard_corpora};
use cfslda::data::synthetic::{generate_split, SyntheticSpec};
use cfslda::eval::metrics::compute;
use cfslda::parallel::worker::{run_worker, WorkerPlan};
use cfslda::runtime::EngineHandle;
use cfslda::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    cfslda::util::logging::init();
    let quick = quick_mode();
    let mut spec = SyntheticSpec::continuous_small();
    spec.docs = if quick { 400 } else { 1200 };
    spec.vocab = if quick { 400 } else { 1000 };
    let mut rng = Pcg64::seed_from_u64(20170710);
    let mut ds: Dataset = generate_split(&spec, spec.docs * 3 / 4, &mut rng);

    let mut cfg = ExperimentConfig::quick();
    cfg.engine = EngineKind::Native;
    cfg.train.sweeps = if quick { 15 } else { 40 };
    cfg.train.burnin = 3;
    cfg.train.eta_every = 3;
    let m = 4usize;
    cfg.parallel.shards = m;
    let engine = EngineHandle::native();

    // Partition, then corrupt shard 0's labels to create heterogeneity.
    let shards = random_shards(ds.train.num_docs(), m, &mut rng);
    for &di in &shards[0] {
        ds.train.responses[di] += 3.0 * rng.next_gaussian();
    }
    let subs = shard_corpora(&ds.train, &shards);

    // Train each shard once; reuse local predictions across schemes.
    let mut preds = Vec::new();
    let mut mses = Vec::new();
    let mut accs = Vec::new();
    for (i, sub) in subs.iter().enumerate() {
        let out = run_worker(
            i,
            sub.view(),
            ds.test.view(),
            ds.train.view(),
            WorkerPlan { predict_test: true, predict_full_train: true },
            &cfg,
            &engine,
            Pcg64::seed_from_u64(cfg.seed).split(i as u64),
        )?;
        preds.push(out.test_pred.unwrap().yhat);
        let (mse, acc) = out.full_train_quality.unwrap();
        mses.push(mse);
        accs.push(acc);
    }
    println!("== ablation: weight schemes (shard 0 label-corrupted) ==");
    println!("per-shard full-train MSE: {mses:?}");
    let ys = ds.test.responses();
    println!("{:<16} {:>10} {:>8} {:>24}", "scheme", "test-MSE", "r2", "weights");
    for (name, rule) in [
        ("uniform", CombineRule::Weighted(WeightScheme::Uniform)),
        ("inverse-mse", CombineRule::Weighted(WeightScheme::InverseMse)),
        ("accuracy", CombineRule::Weighted(WeightScheme::Accuracy)),
    ] {
        let w = weights(rule, &mses, &accs)?;
        let yhat = combine_predictions(&engine, &preds, &w)?;
        let metr = compute(&yhat, &ys);
        let wsum: f64 = w.iter().sum();
        let wn: Vec<f64> = w.iter().map(|x| (x / wsum * 1000.0).round() / 1000.0).collect();
        println!("{:<16} {:>10.4} {:>8.3} {:>24}", name, metr.mse, metr.r2, format!("{wn:?}"));
    }
    // Median combination (extension; robust to the corrupted shard).
    let yhat = combine_median(&preds)?;
    let metr = compute(&yhat, &ys);
    println!("{:<16} {:>10.4} {:>8.3} {:>24}", "median", metr.mse, metr.r2, "-");
    println!("(expect inverse-mse and median to resist shard 0's corruption and beat uniform)");
    Ok(())
}
