//! Ablation bench: topic count sweep T ∈ {8,16,32,64}.
//!
//! T controls both model capacity and hot-path cost (the Gibbs conditional
//! is O(T) per token with T exponentials when the response term is active).
//! Also exercises every AOT topic bucket.

use cfslda::bench_harness::quick_mode;
use cfslda::config::schema::{EngineKind, ExperimentConfig};
use cfslda::data::synthetic::{generate_split, SyntheticSpec};
use cfslda::parallel::leader::{run_with_engine, Algorithm};
use cfslda::runtime::EngineHandle;
use cfslda::util::rng::Pcg64;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    cfslda::util::logging::init();
    let quick = quick_mode();
    let mut spec = SyntheticSpec::mdna();
    if quick {
        spec.docs = 500;
        spec.vocab = 500;
    } else {
        spec.docs = 2000;
        spec.vocab = 2000;
    }
    let mut rng = Pcg64::seed_from_u64(20170710);
    let ds = generate_split(&spec, spec.docs * 3 / 4, &mut rng);

    let dir = std::env::var("CFSLDA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let engine = EngineHandle::from_kind(EngineKind::Auto, Path::new(&dir))?;
    println!(
        "== ablation: topic count (SimpleAverage, engine={}) docs={} ==",
        engine.name(),
        spec.docs
    );
    println!("{:<8} {:>9} {:>10} {:>8} {:>16}", "T", "wall(s)", "test-MSE", "r2", "tokens/s/shard");
    for t in [8usize, 16, 32, 64] {
        let mut cfg = ExperimentConfig::fig6();
        cfg.model.topics = t;
        cfg.train.sweeps = if quick { 15 } else { 40 };
        cfg.train.burnin = 3;
        cfg.train.eta_every = 4;
        let (out, _) = run_with_engine(Algorithm::SimpleAverage, &ds, &cfg, &engine, false)?;
        let tokens: u64 = out.shards.iter().map(|s| s.tokens_sampled).sum();
        let gibbs = out.timings.get("gibbs").max(1e-9);
        println!(
            "{:<8} {:>9.3} {:>10.4} {:>8.3} {:>16.2e}",
            t,
            out.wall_secs,
            out.test_metrics.mse,
            out.test_metrics.r2,
            tokens as f64 / gibbs
        );
    }
    Ok(())
}
