//! Bench: regenerates paper Figs 1-3 as measured experiments (KS distances
//! for pooling demos; Hungarian permutation gap + prediction agreement for
//! the sLDA projection argument), with timings.

use cfslda::bench_harness::{bench, quick_mode, render_table};
use cfslda::config::schema::{EngineKind, ExperimentConfig};
use cfslda::data::partition::train_test_split;
use cfslda::data::synthetic::{generate_corpus, SyntheticSpec};
use cfslda::experiments::fig123;
use cfslda::runtime::EngineHandle;
use cfslda::util::rng::Pcg64;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    cfslda::util::logging::init();
    let quick = quick_mode();
    let n = if quick { 4_000 } else { 20_000 };
    let seed = 20170710u64;

    let mut results = Vec::new();
    let mut f1 = None;
    results.push(bench("fig1_unimodal_pooling", 0, if quick { 2 } else { 5 }, || {
        f1 = Some(fig123::fig1_unimodal(3, n, seed));
    }));
    let mut f2 = None;
    results.push(bench("fig2_multimodal_pooling", 0, if quick { 2 } else { 5 }, || {
        f2 = Some(fig123::fig2_multimodal(n, seed));
    }));

    let spec = SyntheticSpec::continuous_small();
    let mut rng = Pcg64::seed_from_u64(seed);
    let corpus = generate_corpus(&spec, &mut rng);
    let ds = train_test_split(&corpus, spec.docs * 3 / 4, &mut rng);
    let mut cfg = ExperimentConfig::quick();
    cfg.seed = seed;
    let dir = std::env::var("CFSLDA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let engine = EngineHandle::from_kind(EngineKind::Auto, Path::new(&dir))?;
    let mut f3 = None;
    results.push(bench("fig3_slda_projection", 0, 1, || {
        f3 = Some(fig123::fig3_projection(&ds, &cfg, &engine).unwrap());
    }));

    println!("{}", render_table("quasi-ergodicity demos (Figs 1-3)", &results));
    println!("{}", fig123::render(&f1.unwrap(), &f2.unwrap(), &f3.unwrap()));
    Ok(())
}
