//! Bench: engine-dispatch overhead — the AOT XLA artifacts (PJRT, via the
//! service thread) vs the pure-rust native engine, per operation.
//!
//! Validates the architecture claim that engine calls are coarse enough
//! for the service-thread serialization to be immaterial on the request
//! path (calls happen once per eta step / prediction batch).

use cfslda::bench_harness::{bench, quick_mode, render_table};
use cfslda::runtime::native::NativeEngine;
use cfslda::runtime::{EngineHandle, EngineImpl};
use cfslda::util::rng::Pcg64;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    cfslda::util::logging::init();
    let quick = quick_mode();
    let iters = if quick { 5 } else { 20 };
    let dir = std::env::var("CFSLDA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let dir = Path::new(&dir);
    if !dir.join("manifest.json").exists() {
        println!("runtime_engines: SKIP (no artifacts; run `make artifacts`)");
        return Ok(());
    }
    let xla = EngineHandle::xla(dir)?;
    let native = NativeEngine::new();

    let mut rng = Pcg64::seed_from_u64(1);
    let (d, t, m) = (3000usize, 16usize, 4usize);
    let zbar: Vec<f32> = (0..d * t).map(|_| rng.next_f32()).collect();
    let y: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
    let eta: Vec<f64> = (0..t).map(|_| rng.next_gaussian()).collect();
    let preds: Vec<Vec<f64>> =
        (0..m).map(|_| (0..d).map(|_| rng.next_gaussian()).collect()).collect();
    let w = vec![1.0f64; m];

    let mut results = Vec::new();
    results.push(bench("eta_solve/xla D=3000 T=16", 2, iters, || {
        xla.eta_solve(&zbar, &y, t, 0.5, 0.0).unwrap();
    }));
    results.push(bench("eta_solve/native D=3000 T=16", 2, iters, || {
        native.eta_solve(&zbar, &y, t, 0.5, 0.0).unwrap();
    }));
    results.push(bench("predict/xla B=3000", 2, iters, || {
        xla.predict(&zbar, &eta, Some(&y), t).unwrap();
    }));
    results.push(bench("predict/native B=3000", 2, iters, || {
        native.predict(&zbar, &eta, Some(&y), t).unwrap();
    }));
    results.push(bench("combine/xla M=4 B=3000", 2, iters, || {
        xla.combine(&preds, &w).unwrap();
    }));
    results.push(bench("combine/native M=4 B=3000", 2, iters, || {
        native.combine(&preds, &w).unwrap();
    }));
    // chunked path: two row buckets
    let dbig = 6000usize;
    let zbig: Vec<f32> = (0..dbig * t).map(|_| rng.next_f32()).collect();
    let ybig: Vec<f64> = (0..dbig).map(|_| rng.next_gaussian()).collect();
    results.push(bench("eta_solve/xla chunked D=6000", 1, iters.min(8), || {
        xla.eta_solve(&zbig, &ybig, t, 0.5, 0.0).unwrap();
    }));

    println!("{}", render_table("engine ops: XLA artifacts vs native", &results));
    Ok(())
}
