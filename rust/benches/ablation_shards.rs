//! Ablation bench (beyond the paper): shard count sweep M ∈ {2,4,8,16}.
//!
//! The paper fixes M = 4 (dual-core, 4 threads). This sweep shows the
//! speed/quality trade-off as shards shrink: training time falls ~1/M while
//! Simple Average quality degrades gracefully as each local posterior sees
//! fewer documents.

use cfslda::bench_harness::quick_mode;
use cfslda::config::schema::{EngineKind, ExperimentConfig};
use cfslda::data::synthetic::{generate_split, SyntheticSpec};
use cfslda::parallel::leader::{run_with_engine, Algorithm};
use cfslda::runtime::EngineHandle;
use cfslda::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    cfslda::util::logging::init();
    let quick = quick_mode();
    let mut spec = SyntheticSpec::mdna();
    if quick {
        spec.docs = 600;
        spec.vocab = 600;
    }
    let mut rng = Pcg64::seed_from_u64(20170710);
    let n_train = spec.docs * 3 / 4;
    let ds = generate_split(&spec, n_train, &mut rng);

    let mut cfg = ExperimentConfig::fig6();
    cfg.engine = EngineKind::Native; // isolate the sharding effect
    cfg.model.topics = 16;
    cfg.train.sweeps = if quick { 20 } else { 60 };
    cfg.train.burnin = 4;
    cfg.train.eta_every = 4;
    let engine = EngineHandle::native();

    println!("== ablation: shard count (SimpleAverage vs NonParallel), docs={} ==", spec.docs);
    println!("{:<14} {:>9} {:>10} {:>8} {:>12}", "arm", "wall(s)", "test-MSE", "r2", "comm(MB)");
    let (base, _) = run_with_engine(Algorithm::NonParallel, &ds, &cfg, &engine, false)?;
    println!(
        "{:<14} {:>9.3} {:>10.4} {:>8.3} {:>12.2}",
        "non-parallel", base.wall_secs, base.test_metrics.mse, base.test_metrics.r2, 0.0
    );
    for m in [2usize, 4, 8, 16] {
        let mut c = cfg.clone();
        c.parallel.shards = m;
        c.parallel.threads = m.min(8);
        let (out, _) = run_with_engine(Algorithm::SimpleAverage, &ds, &c, &engine, false)?;
        println!(
            "{:<14} {:>9.3} {:>10.4} {:>8.3} {:>12.2}",
            format!("simple M={m}"),
            out.wall_secs,
            out.test_metrics.mse,
            out.test_metrics.r2,
            out.comm.total() as f64 / 1e6
        );
    }
    Ok(())
}
