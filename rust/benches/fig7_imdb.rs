//! Bench: regenerates paper Fig 7 — computation time + test accuracy for
//! the four algorithms on the Experiment-II (reviews -> sentiment) workload.
//!
//! Full scale: 25k docs (paper), 2 repeats. CI scale: `-- --quick`.

use cfslda::bench_harness::quick_mode;
use cfslda::config::schema::EngineKind;
use cfslda::experiments::runner::{check_fig_shape, render_table, run_comparison, Comparison};
use cfslda::runtime::EngineHandle;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    cfslda::util::logging::init();
    let quick = quick_mode();
    let (scale, runs, sweeps) = if quick { (0.06, 1, 15) } else { (1.0, 2, 60) };
    let mut c = Comparison::fig7(scale, runs);
    c.cfg.train.sweeps = sweeps;
    c.cfg.train.burnin = (sweeps / 10).max(2);
    let dir = std::env::var("CFSLDA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let engine = EngineHandle::from_kind(EngineKind::Auto, Path::new(&dir))?;
    eprintln!(
        "fig7 bench: docs={} vocab={} sweeps={} runs={} engine={} (quick={quick})",
        c.spec.docs, c.spec.vocab, sweeps, runs, engine.name()
    );
    let (series, _) = run_comparison(&c, &engine)?;
    println!("{}", render_table("Fig 7: reviews -> sentiment, four algorithms", &series, true));
    match check_fig_shape(&series, true) {
        Ok(()) => println!("fig7 shape: PASS"),
        Err(e) => println!("fig7 shape: MARGINAL at this scale — {e}"),
    }
    Ok(())
}
