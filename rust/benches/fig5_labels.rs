//! Bench: regenerates paper Fig 5 — the label histogram of the Experiment-I
//! corpus with normality statistics — and measures corpus-generation
//! throughput (tokens/s) of the synthetic sLDA sampler.

use cfslda::bench_harness::{bench_throughput, quick_mode, render_table};
use cfslda::data::synthetic::{generate_corpus, SyntheticSpec};
use cfslda::experiments::fig5;
use cfslda::util::rng::Pcg64;

fn main() {
    cfslda::util::logging::init();
    let quick = quick_mode();
    let mut spec = SyntheticSpec::mdna();
    if quick {
        spec.docs = 800;
        spec.vocab = 800;
    }

    let report = fig5::fig5_labels(&spec, 40, 20170710);
    println!("{}", fig5::render(&report, &spec));

    // generation throughput (supports the "synthetic corpus is cheap" claim)
    let tokens = spec.docs as f64 * spec.doc_len_mean;
    let mut seed = 0u64;
    let r = bench_throughput("corpus_generation", 1, if quick { 2 } else { 4 }, tokens, || {
        seed += 1;
        let mut rng = Pcg64::seed_from_u64(seed);
        let c = generate_corpus(&spec, &mut rng);
        std::hint::black_box(c.num_tokens());
    });
    println!("{}", render_table("Fig 5 workload generation", &[r]));
}
