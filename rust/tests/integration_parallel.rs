//! Parallel-algorithm integration: the paper's qualitative results must
//! hold on synthetic corpora — Naive Combination degraded by
//! quasi-ergodicity, prediction-space combination preserving quality, and
//! the timing order (Fig 6/7 shape).

use cfslda::config::schema::{EngineKind, ExperimentConfig, ResponseKind};
use cfslda::data::synthetic::{generate_split, SyntheticSpec};
use cfslda::eval::mode_diag::mode_divergence;
use cfslda::experiments::runner::{check_fig_shape, run_comparison, Comparison};
use cfslda::parallel::leader::{run_with_engine, Algorithm};
use cfslda::runtime::EngineHandle;
use cfslda::util::rng::Pcg64;

/// Wall-clock assertions need exclusive use of the CPU: the test harness
/// runs tests concurrently, so every test in this binary takes this lock
/// (timing-sensitive ones would otherwise measure each other's contention).
static TIMING_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    TIMING_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::quick();
    c.engine = EngineKind::Native;
    c.train.sweeps = 20;
    c.train.burnin = 4;
    c.train.eta_every = 4;
    c.train.predict_sweeps = 10;
    c.train.predict_burnin = 3;
    c.parallel.shards = 4;
    c.parallel.threads = 4;
    c
}

#[test]
fn fig6_shape_holds_continuous() {
    let _guard = serial();
    // Large enough that training dominates thread-spawn overhead and the
    // paper's timing order is measurable (full scale lives in the benches).
    let mut c = Comparison::fig6(0.5, 2); // ~2100 docs, 2 runs
    c.cfg = cfg();
    c.cfg.model.topics = 8;
    c.cfg.train.sweeps = 40;
    c.cfg.train.burnin = 5;
    c.cfg.train.eta_every = 5;
    let engine = EngineHandle::native();
    let (series, _) = run_comparison(&c, &engine).unwrap();
    check_fig_shape(&series, false).unwrap();
}

#[test]
fn fig7_shape_holds_binary() {
    let _guard = serial();
    let mut c = Comparison::fig7(0.08, 2); // ~2000 docs
    c.cfg = cfg();
    c.cfg.response = ResponseKind::Binary;
    c.cfg.model.topics = 8;
    c.cfg.train.sweeps = 40;
    c.cfg.train.burnin = 5;
    c.cfg.train.eta_every = 5;
    let engine = EngineHandle::native();
    let (series, _) = run_comparison(&c, &engine).unwrap();
    check_fig_shape(&series, true).unwrap();
}

#[test]
fn quasi_ergodicity_is_why_naive_fails() {
    let _guard = serial();
    // Causal chain check: shards actually sit in different permutation
    // modes (positive Hungarian gap) AND naive does worse than simple.
    let spec = SyntheticSpec::continuous_small();
    let mut rng = Pcg64::seed_from_u64(11);
    let ds = generate_split(&spec, 200, &mut rng);
    let engine = EngineHandle::native();
    let c = cfg();

    let (simple, models) =
        run_with_engine(Algorithm::SimpleAverage, &ds, &c, &engine, true).unwrap();
    let phis: Vec<_> = models.iter().map(|m| m.phi_topic_rows()).collect();
    let div = mode_divergence(&phis);
    assert!(div.permutation_gap() > 0.03, "no mode divergence measured: {div:?}");

    let (naive, _) =
        run_with_engine(Algorithm::NaiveCombination, &ds, &c, &engine, false).unwrap();
    assert!(
        naive.test_metrics.mse > simple.test_metrics.mse,
        "naive {} <= simple {}",
        naive.test_metrics.mse,
        simple.test_metrics.mse
    );
}

#[test]
fn more_shards_keep_simple_average_quality() {
    let _guard = serial();
    // Robustness beyond the paper: quality should degrade gracefully (not
    // collapse) as M grows and shards shrink.
    let mut spec = SyntheticSpec::continuous_small();
    spec.docs = 480;
    let mut rng = Pcg64::seed_from_u64(13);
    let ds = generate_split(&spec, 400, &mut rng);
    let engine = EngineHandle::native();
    let ys = ds.test.responses();
    let var = cfslda::util::stats::Summary::from_slice(&ys).var();
    for m in [2usize, 4, 8] {
        let mut c = cfg();
        c.parallel.shards = m;
        let (out, _) = run_with_engine(Algorithm::SimpleAverage, &ds, &c, &engine, false).unwrap();
        assert!(
            out.test_metrics.mse < var,
            "M={m}: mse {} worse than mean baseline {var}",
            out.test_metrics.mse
        );
    }
}

#[test]
fn single_shard_parallel_equals_shape_of_nonparallel() {
    let _guard = serial();
    // M=1 SimpleAverage is NonParallel plus combination overhead; quality
    // must be statistically indistinguishable (same corpus, same budget).
    let spec = SyntheticSpec::continuous_small();
    let mut rng = Pcg64::seed_from_u64(17);
    let ds = generate_split(&spec, 180, &mut rng);
    let engine = EngineHandle::native();
    let mut c = cfg();
    c.parallel.shards = 1;
    let (simple, _) = run_with_engine(Algorithm::SimpleAverage, &ds, &c, &engine, false).unwrap();
    let (nonp, _) = run_with_engine(Algorithm::NonParallel, &ds, &c, &engine, false).unwrap();
    // Different RNG consumption (partition + split streams) makes the runs
    // stochastically different; both must beat the mean-predictor baseline.
    let var = cfslda::util::stats::Summary::from_slice(&ds.test.responses()).var();
    assert!(simple.test_metrics.mse < 0.6 * var, "M=1 simple {} vs var {var}", simple.test_metrics.mse);
    assert!(nonp.test_metrics.mse < 0.6 * var, "nonparallel {} vs var {var}", nonp.test_metrics.mse);
}

#[test]
fn threads_do_not_change_results() {
    let _guard = serial();
    // Thread count is a resource knob, never a semantics knob.
    let spec = SyntheticSpec::continuous_small();
    let mut rng = Pcg64::seed_from_u64(19);
    let ds = generate_split(&spec, 180, &mut rng);
    let engine = EngineHandle::native();
    let mut c1 = cfg();
    c1.parallel.threads = 1;
    let mut c4 = cfg();
    c4.parallel.threads = 4;
    let (a, _) = run_with_engine(Algorithm::WeightedAverage, &ds, &c1, &engine, false).unwrap();
    let (b, _) = run_with_engine(Algorithm::WeightedAverage, &ds, &c4, &engine, false).unwrap();
    assert_eq!(a.yhat, b.yhat);
    assert_eq!(a.weights, b.weights);
}

#[test]
fn full_stack_with_xla_engine_when_available() {
    let _guard = serial();
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let spec = SyntheticSpec::continuous_small();
    let mut rng = Pcg64::seed_from_u64(23);
    let ds = generate_split(&spec, 180, &mut rng);
    let engine = EngineHandle::xla(dir).unwrap();
    let c = cfg();
    for algo in Algorithm::ALL {
        let (out, _) = run_with_engine(algo, &ds, &c, &engine, false).unwrap();
        assert!(out.test_metrics.mse.is_finite());
        assert_eq!(out.yhat.len(), ds.test.num_docs());
    }
}
