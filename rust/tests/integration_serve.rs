//! End-to-end serving tests: train a real model, boot the server on an
//! ephemeral loopback port, and exercise every endpoint over actual HTTP —
//! determinism across repeated/reshaped requests, hot-swap with zero
//! dropped requests, raw-text prediction through the persisted vocabulary.

use cfslda::config::json;
use cfslda::config::schema::{ExperimentConfig, ServeBackend};
use cfslda::data::synthetic::{generate_corpus, SyntheticSpec};
use cfslda::data::vocab::Vocab;
use cfslda::model::persist::save_model_with_vocab;
use cfslda::model::slda::SldaModel;
use cfslda::runtime::EngineHandle;
use cfslda::sampler::gibbs_train::train;
use cfslda::serve::http::{request_once, Client};
use cfslda::serve::server::Server;
use cfslda::util::pool::scoped_map;
use cfslda::util::rng::Pcg64;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("cfslda_it_serve_{}_{name}", std::process::id()));
    p
}

fn quick_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::quick();
    c.train.sweeps = 20;
    c.train.burnin = 4;
    c.train.predict_sweeps = 8;
    c.train.predict_burnin = 2;
    c.serve.addr = "127.0.0.1:0".to_string();
    c.serve.workers = 2;
    c.serve.max_batch = 8;
    c.serve.max_wait_us = 200;
    c.serve.cache_capacity = 256;
    c
}

/// Train a small model and persist it (with a synthetic vocabulary so the
/// text endpoint is exercised too). Returns (model_path, model).
fn trained_model(name: &str, seed: u64) -> (PathBuf, SldaModel) {
    let spec = SyntheticSpec::continuous_small();
    let mut rng = Pcg64::seed_from_u64(seed);
    let corpus = generate_corpus(&spec, &mut rng);
    let engine = EngineHandle::native();
    let out = train(&corpus, &quick_cfg(), &engine, &mut rng).unwrap();
    let vocab =
        Vocab::from_terms((0..out.model.w).map(|i| format!("word{i}"))).unwrap();
    let path = tmp(name);
    save_model_with_vocab(&out.model, Some(&vocab), &path).unwrap();
    (path, out.model)
}

fn yhat_of(body: &str) -> Vec<f64> {
    let v = json::parse(body).unwrap();
    v.get("yhat").unwrap().as_array().unwrap().iter().map(|x| x.as_f64().unwrap()).collect()
}

#[test]
fn serve_round_trip_determinism_and_hot_swap() {
    let (path, model) = trained_model("rt.bin", 1);
    let server = Server::start(&path, &quick_cfg()).unwrap();
    let addr = server.local_addr().to_string();

    // healthz
    let (status, body) = request_once(&addr, "GET", "/healthz", "").unwrap();
    assert_eq!(status, 200, "{body}");
    let v = json::parse(&body).unwrap();
    assert_eq!(v.get("status").unwrap().as_str(), Some("ok"));
    assert_eq!(v.get("model_version").unwrap().as_usize(), Some(1));
    assert_eq!(v.get("has_vocab_terms").unwrap().as_bool(), Some(true));

    // deterministic predictions: same request twice -> identical yhat
    let mut client = Client::connect(&addr).unwrap();
    let req = r#"{"docs": [[0, 1, 2, 3, 1], [4, 4, 5]], "seed": 7}"#;
    let (s1, b1) = client.request("POST", "/predict", req).unwrap();
    let (s2, b2) = client.request("POST", "/predict", req).unwrap();
    assert_eq!(s1, 200, "{b1}");
    assert_eq!(s2, 200);
    let y1 = yhat_of(&b1);
    assert_eq!(y1.len(), 2);
    assert!(y1.iter().all(|y| y.is_finite()));
    assert_eq!(y1, yhat_of(&b2), "repeat request must be byte-deterministic");
    // second pass came from the cache but reports the same numbers
    let v2 = json::parse(&b2).unwrap();
    assert_eq!(v2.get("cached").unwrap().as_usize(), Some(2));

    // batch-shape independence: the same docs sent one at a time
    let (_, ba) = client.request("POST", "/predict", r#"{"docs": [[0, 1, 2, 3, 1]], "seed": 7}"#).unwrap();
    let (_, bb) = client.request("POST", "/predict", r#"{"docs": [[4, 4, 5]], "seed": 7}"#).unwrap();
    assert_eq!(y1, vec![yhat_of(&ba)[0], yhat_of(&bb)[0]]);

    // text endpoint through the persisted vocabulary ("word0" -> id 0 ...)
    let (st, bt) = client
        .request("POST", "/predict/text", r#"{"texts": ["word0 word1 word2 word3 word1"], "seed": 7}"#)
        .unwrap();
    assert_eq!(st, 200, "{bt}");
    // tokenization lowercases and keeps these synthetic terms intact, so
    // this is exactly doc [0, 1, 2, 3, 1]:
    assert_eq!(yhat_of(&bt)[0], y1[0]);

    // malformed / out-of-contract requests -> 400 with an error body
    for bad in [
        "not json",
        r#"{"docs": []}"#,
        r#"{"docs": [[]]}"#,
        r#"{"docs": [[999999]]}"#, // out of vocab
        r#"{"texts": ["zzz qqq"]}"#,
    ] {
        let path = if bad.contains("texts") { "/predict/text" } else { "/predict" };
        let (s, b) = client.request("POST", path, bad).unwrap();
        assert_eq!(s, 400, "{bad} -> {b}");
        assert!(json::parse(&b).unwrap().get("error").is_some());
    }
    let (s404, _) = client.request("GET", "/nope", "").unwrap();
    assert_eq!(s404, 404);

    // hot swap to a second model while clients keep hammering: zero
    // dropped requests, versions only ever move forward
    let (path2, model2) = trained_model("rt2.bin", 2);
    assert_ne!(model.eta, model2.eta);
    let ids: Vec<usize> = (0..4).collect();
    let results = scoped_map(&ids, 4, |i, _| {
        if i == 0 {
            // the swapper
            let (s, b) = request_once(
                &addr,
                "POST",
                "/reload",
                &format!(r#"{{"path": "{}"}}"#, path2.display()),
            )
            .unwrap();
            assert_eq!(s, 200, "{b}");
            let v = json::parse(&b).unwrap();
            assert_eq!(v.get("model_version").unwrap().as_usize(), Some(2));
            Vec::new()
        } else {
            let mut c = Client::connect(&addr).unwrap();
            let mut versions = Vec::new();
            for _ in 0..20 {
                let (s, b) = c.request("POST", "/predict", req).unwrap();
                assert_eq!(s, 200, "dropped request during hot swap: {b}");
                let v = json::parse(&b).unwrap();
                versions.push(v.get("model_version").unwrap().as_usize().unwrap());
                assert!(yhat_of(&b).iter().all(|y| y.is_finite()));
            }
            versions
        }
    });
    for versions in results.iter().skip(1) {
        assert!(versions.windows(2).all(|ab| ab[0] <= ab[1]), "version went backwards");
        assert!(versions.iter().all(|&v| v == 1 || v == 2));
    }
    // after the swap, the same request routes to the new model
    let (sv, bv) = request_once(&addr, "GET", "/healthz", "").unwrap();
    assert_eq!(sv, 200);
    assert_eq!(json::parse(&bv).unwrap().get("model_version").unwrap().as_usize(), Some(2));

    // stats reflect the traffic
    let (ss, bs) = request_once(&addr, "GET", "/stats", "").unwrap();
    assert_eq!(ss, 200);
    let sv = json::parse(&bs).unwrap();
    assert!(sv.get("requests").unwrap().as_f64().unwrap() >= 60.0);
    assert!(sv.get("predict_docs").unwrap().as_f64().unwrap() >= 60.0);
    assert!(sv.get("batches").unwrap().as_f64().unwrap() >= 1.0);
    assert_eq!(sv.get("reloads").unwrap().as_usize(), Some(1));
    assert_eq!(sv.get("workers").unwrap().as_usize(), Some(2));
    assert!(sv.get("versions").unwrap().as_array().unwrap().len() >= 2);

    server.stop();
    std::fs::remove_file(path).ok();
    std::fs::remove_file(path2).ok();
}

#[test]
fn predictions_survive_server_restart() {
    // Resident state (cache, scratch) must not leak into results: a fresh
    // server on the same model file reproduces the same predictions.
    let (path, _model) = trained_model("restart.bin", 3);
    let req = r#"{"docs": [[1, 2, 3], [6, 5, 6, 5]], "seed": 42}"#;
    let run = || {
        let server = Server::start(&path, &quick_cfg()).unwrap();
        let addr = server.local_addr().to_string();
        let (s, b) = request_once(&addr, "POST", "/predict", req).unwrap();
        assert_eq!(s, 200, "{b}");
        let y = yhat_of(&b);
        server.stop();
        y
    };
    let a = run();
    let b = run();
    assert_eq!(a, b);
    std::fs::remove_file(path).ok();
}

fn backend_cfg(backend: ServeBackend) -> ExperimentConfig {
    let mut c = quick_cfg();
    c.serve.backend = backend;
    c
}

/// Read one full HTTP/1.1 response (status line + headers +
/// `Content-Length` body) as raw bytes, for byte-level comparisons the
/// convenience [`Client`] can't make.
fn read_raw_response(stream: &mut TcpStream) -> Vec<u8> {
    let mut raw = Vec::new();
    let mut byte = [0u8; 1];
    // Head, one byte at a time (responses are small; simplicity wins).
    while !raw.ends_with(b"\r\n\r\n") {
        match stream.read(&mut byte) {
            Ok(1) => raw.push(byte[0]),
            other => panic!("connection ended mid-head: {other:?}"),
        }
    }
    let head = String::from_utf8_lossy(&raw).to_string();
    let content_length: usize = head
        .lines()
        .find_map(|l| l.strip_prefix("Content-Length: "))
        .expect("Content-Length header")
        .trim()
        .parse()
        .unwrap();
    let mut body = vec![0u8; content_length];
    stream.read_exact(&mut body).unwrap();
    raw.extend_from_slice(&body);
    raw
}

/// The determinism contract across connection engines: for the same
/// (model, seed, doc) the threads and epoll backends must produce
/// byte-identical response bodies — including across a keep-alive
/// session with error responses interleaved, and for two requests
/// pipelined into a single TCP segment.
#[test]
fn backends_serve_byte_identical_responses() {
    let (path, _model) = trained_model("beq.bin", 11);
    let reqs: Vec<(&str, &str, &str)> = vec![
        ("POST", "/predict", r#"{"docs": [[0, 1, 2, 3, 1], [4, 4, 5]], "seed": 7}"#),
        ("POST", "/predict", r#"{"docs": [[2, 2, 2]], "seed": 9}"#),
        ("POST", "/predict/text", r#"{"texts": ["word0 word1 word2"], "seed": 7}"#),
        ("POST", "/predict", "not json"),
        ("GET", "/healthz", ""),
        ("GET", "/nope", ""),
        ("POST", "/predict", r#"{"docs": [[5, 6, 5]], "seed": 3}"#),
    ];
    let pipelined_body = r#"{"docs": [[1, 2, 3]], "seed": 5}"#;
    let run = |backend: ServeBackend| {
        let server = Server::start(&path, &backend_cfg(backend)).unwrap();
        let addr = server.local_addr().to_string();
        // one keep-alive session carrying the whole mixed sequence
        let mut client = Client::connect(&addr).unwrap();
        let session: Vec<(u16, String)> = reqs
            .iter()
            .map(|(m, p, b)| client.request(m, p, b).unwrap())
            .collect();
        // two identical requests written in a single TCP segment: the
        // server must answer both, in order
        let one = format!(
            "POST /predict HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
            pipelined_body.len(),
            pipelined_body
        );
        let mut s = TcpStream::connect(&addr).unwrap();
        s.set_nodelay(true).ok();
        s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        s.write_all(format!("{one}{one}").as_bytes()).unwrap();
        let r1 = read_raw_response(&mut s);
        let r2 = read_raw_response(&mut s);
        server.stop();
        (session, r1, r2)
    };
    let (threads, t1, t2) = run(ServeBackend::Threads);
    let (epoll, e1, e2) = run(ServeBackend::Epoll);
    let statuses: Vec<u16> = threads.iter().map(|(s, _)| *s).collect();
    assert_eq!(statuses, vec![200, 200, 200, 400, 200, 404, 200]);
    assert_eq!(threads, epoll, "keep-alive session bodies must be byte-identical");
    assert!(t1.starts_with(b"HTTP/1.1 200"), "{}", String::from_utf8_lossy(&t1));
    assert!(t2.starts_with(b"HTTP/1.1 200"), "{}", String::from_utf8_lossy(&t2));
    // Note t1 != t2 is expected: the repeat lands in the doc cache and the
    // response says so. What must hold is backend-for-backend identity.
    assert_eq!((t1, t2), (e1, e2), "pipelined responses must match across backends");
    std::fs::remove_file(path).ok();
}

/// Slow-loris coverage on both backends: a request trickled in across
/// many syscalls (split header, byte-at-a-time body) that stays inside
/// the read deadline still succeeds; a body that stalls forever is
/// terminated by `read_timeout_ms`; an idle keep-alive connection is
/// reaped by `idle_timeout_ms`.
#[test]
fn slow_requests_complete_and_stalled_ones_are_reaped() {
    let (path, _model) = trained_model("loris.bin", 12);
    for backend in [ServeBackend::Threads, ServeBackend::Epoll] {
        let mut cfg = backend_cfg(backend);
        cfg.serve.read_timeout_ms = 600;
        cfg.serve.idle_timeout_ms = 700;
        let server = Server::start(&path, &cfg).unwrap();
        let addr = server.local_addr().to_string();

        let body = r#"{"docs": [[1, 2, 3]], "seed": 5}"#;
        let head = format!(
            "POST /predict HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n",
            body.len()
        );

        // trickled-but-live request: header split mid-line across writes,
        // then the body one small chunk at a time — finishes well inside
        // the 600ms deadline, so it must be answered normally
        let mut s = TcpStream::connect(&addr).unwrap();
        s.set_nodelay(true).ok();
        s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        let (h1, h2) = head.split_at(head.len() / 2);
        s.write_all(h1.as_bytes()).unwrap();
        std::thread::sleep(Duration::from_millis(40));
        s.write_all(h2.as_bytes()).unwrap();
        for chunk in body.as_bytes().chunks(4) {
            std::thread::sleep(Duration::from_millis(5));
            s.write_all(chunk).unwrap();
        }
        let resp = read_raw_response(&mut s);
        assert!(
            resp.starts_with(b"HTTP/1.1 200"),
            "{backend:?}: {}",
            String::from_utf8_lossy(&resp)
        );

        // ...then go idle on the same keep-alive connection: the idle
        // timer must close it (a blocking read observes EOF/reset)
        let t0 = Instant::now();
        let mut buf = [0u8; 64];
        match s.read(&mut buf) {
            Ok(0) | Err(_) => {}
            Ok(n) => panic!("{backend:?}: unexpected {n} bytes on an idle connection"),
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "{backend:?}: idle connection not reaped"
        );

        // stalled body: headers promise more bytes than ever arrive. The
        // read deadline must terminate the connection (threads answers a
        // 400 first; epoll just closes) instead of pinning it forever.
        let mut s2 = TcpStream::connect(&addr).unwrap();
        s2.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        s2.write_all(head.as_bytes()).unwrap();
        s2.write_all(&body.as_bytes()[..4]).unwrap();
        let t0 = Instant::now();
        let mut leftover = Vec::new();
        s2.read_to_end(&mut leftover).ok();
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "{backend:?}: stalled body not reaped"
        );
        if !leftover.is_empty() {
            assert!(
                leftover.starts_with(b"HTTP/1.1 4"),
                "{backend:?}: {}",
                String::from_utf8_lossy(&leftover)
            );
        }
        server.stop();
    }
    std::fs::remove_file(path).ok();
}

/// Admission control on both backends: past `max_conns` open
/// connections, new arrivals are shed at accept with a `503` carrying
/// `Retry-After`, the shed counter moves, and established connections
/// keep working. `/healthz` flips to `"draining"` once a graceful drain
/// begins.
#[test]
fn admission_sheds_with_retry_after_past_max_conns() {
    let (path, _model) = trained_model("admit.bin", 13);
    for backend in [ServeBackend::Threads, ServeBackend::Epoll] {
        let mut cfg = backend_cfg(backend);
        cfg.serve.max_conns = 2;
        let server = Server::start(&path, &cfg).unwrap();
        let addr = server.local_addr().to_string();

        // occupy both slots; a completed request proves each connection
        // is registered against the open-connections gauge
        let mut c1 = Client::connect(&addr).unwrap();
        let mut c2 = Client::connect(&addr).unwrap();
        assert_eq!(c1.request("GET", "/healthz", "").unwrap().0, 200);
        assert_eq!(c2.request("GET", "/healthz", "").unwrap().0, 200);

        // the third connection is shed at accept — the 503 arrives
        // without the client sending a byte, then the socket closes
        let mut s = TcpStream::connect(&addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        let mut raw = Vec::new();
        s.read_to_end(&mut raw).unwrap();
        let text = String::from_utf8_lossy(&raw);
        assert!(text.starts_with("HTTP/1.1 503"), "{backend:?}: {text}");
        assert!(text.contains("Retry-After: 1\r\n"), "{backend:?}: {text}");
        assert!(text.contains("Connection: close"), "{backend:?}: {text}");
        assert!(text.contains("overloaded"), "{backend:?}: {text}");
        assert_eq!(server.metrics().shed.get(), 1, "{backend:?}");
        assert_eq!(server.metrics().open_connections.get(), 2, "{backend:?}");

        // established connections are unaffected by the shed
        assert_eq!(c1.request("POST", "/predict", r#"{"docs": [[0, 1]]}"#).unwrap().0, 200);

        // graceful drain: healthz reports draining so load balancers
        // stop routing here, while existing connections keep being served
        server.begin_drain();
        let (st, b) = c1.request("GET", "/healthz", "").unwrap();
        assert_eq!(st, 200);
        assert_eq!(json::parse(&b).unwrap().get("status").unwrap().as_str(), Some("draining"));
        server.stop();
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn model_without_vocab_rejects_text_endpoint() {
    let spec = SyntheticSpec::continuous_small();
    let mut rng = Pcg64::seed_from_u64(5);
    let corpus = generate_corpus(&spec, &mut rng);
    let engine = EngineHandle::native();
    let out = train(&corpus, &quick_cfg(), &engine, &mut rng).unwrap();
    let path = tmp("novocab.bin");
    save_model_with_vocab(&out.model, None, &path).unwrap();
    let server = Server::start(&path, &quick_cfg()).unwrap();
    let addr = server.local_addr().to_string();
    let (s, b) = request_once(&addr, "POST", "/predict/text", r#"{"texts": ["hello"]}"#).unwrap();
    assert_eq!(s, 400);
    assert!(b.contains("vocabulary"), "{b}");
    // BoW prediction still works
    let (s, b) = request_once(&addr, "POST", "/predict", r#"{"docs": [[0, 1]]}"#).unwrap();
    assert_eq!(s, 200, "{b}");
    server.stop();
    std::fs::remove_file(path).ok();
}
