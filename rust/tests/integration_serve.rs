//! End-to-end serving tests: train a real model, boot the server on an
//! ephemeral loopback port, and exercise every endpoint over actual HTTP —
//! determinism across repeated/reshaped requests, hot-swap with zero
//! dropped requests, raw-text prediction through the persisted vocabulary.

use cfslda::config::json;
use cfslda::config::schema::ExperimentConfig;
use cfslda::data::synthetic::{generate_corpus, SyntheticSpec};
use cfslda::data::vocab::Vocab;
use cfslda::model::persist::save_model_with_vocab;
use cfslda::model::slda::SldaModel;
use cfslda::runtime::EngineHandle;
use cfslda::sampler::gibbs_train::train;
use cfslda::serve::http::{request_once, Client};
use cfslda::serve::server::Server;
use cfslda::util::pool::scoped_map;
use cfslda::util::rng::Pcg64;
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("cfslda_it_serve_{}_{name}", std::process::id()));
    p
}

fn quick_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::quick();
    c.train.sweeps = 20;
    c.train.burnin = 4;
    c.train.predict_sweeps = 8;
    c.train.predict_burnin = 2;
    c.serve.addr = "127.0.0.1:0".to_string();
    c.serve.workers = 2;
    c.serve.max_batch = 8;
    c.serve.max_wait_us = 200;
    c.serve.cache_capacity = 256;
    c
}

/// Train a small model and persist it (with a synthetic vocabulary so the
/// text endpoint is exercised too). Returns (model_path, model).
fn trained_model(name: &str, seed: u64) -> (PathBuf, SldaModel) {
    let spec = SyntheticSpec::continuous_small();
    let mut rng = Pcg64::seed_from_u64(seed);
    let corpus = generate_corpus(&spec, &mut rng);
    let engine = EngineHandle::native();
    let out = train(&corpus, &quick_cfg(), &engine, &mut rng).unwrap();
    let vocab =
        Vocab::from_terms((0..out.model.w).map(|i| format!("word{i}"))).unwrap();
    let path = tmp(name);
    save_model_with_vocab(&out.model, Some(&vocab), &path).unwrap();
    (path, out.model)
}

fn yhat_of(body: &str) -> Vec<f64> {
    let v = json::parse(body).unwrap();
    v.get("yhat").unwrap().as_array().unwrap().iter().map(|x| x.as_f64().unwrap()).collect()
}

#[test]
fn serve_round_trip_determinism_and_hot_swap() {
    let (path, model) = trained_model("rt.bin", 1);
    let server = Server::start(&path, &quick_cfg()).unwrap();
    let addr = server.local_addr().to_string();

    // healthz
    let (status, body) = request_once(&addr, "GET", "/healthz", "").unwrap();
    assert_eq!(status, 200, "{body}");
    let v = json::parse(&body).unwrap();
    assert_eq!(v.get("status").unwrap().as_str(), Some("ok"));
    assert_eq!(v.get("model_version").unwrap().as_usize(), Some(1));
    assert_eq!(v.get("has_vocab_terms").unwrap().as_bool(), Some(true));

    // deterministic predictions: same request twice -> identical yhat
    let mut client = Client::connect(&addr).unwrap();
    let req = r#"{"docs": [[0, 1, 2, 3, 1], [4, 4, 5]], "seed": 7}"#;
    let (s1, b1) = client.request("POST", "/predict", req).unwrap();
    let (s2, b2) = client.request("POST", "/predict", req).unwrap();
    assert_eq!(s1, 200, "{b1}");
    assert_eq!(s2, 200);
    let y1 = yhat_of(&b1);
    assert_eq!(y1.len(), 2);
    assert!(y1.iter().all(|y| y.is_finite()));
    assert_eq!(y1, yhat_of(&b2), "repeat request must be byte-deterministic");
    // second pass came from the cache but reports the same numbers
    let v2 = json::parse(&b2).unwrap();
    assert_eq!(v2.get("cached").unwrap().as_usize(), Some(2));

    // batch-shape independence: the same docs sent one at a time
    let (_, ba) = client.request("POST", "/predict", r#"{"docs": [[0, 1, 2, 3, 1]], "seed": 7}"#).unwrap();
    let (_, bb) = client.request("POST", "/predict", r#"{"docs": [[4, 4, 5]], "seed": 7}"#).unwrap();
    assert_eq!(y1, vec![yhat_of(&ba)[0], yhat_of(&bb)[0]]);

    // text endpoint through the persisted vocabulary ("word0" -> id 0 ...)
    let (st, bt) = client
        .request("POST", "/predict/text", r#"{"texts": ["word0 word1 word2 word3 word1"], "seed": 7}"#)
        .unwrap();
    assert_eq!(st, 200, "{bt}");
    // tokenization lowercases and keeps these synthetic terms intact, so
    // this is exactly doc [0, 1, 2, 3, 1]:
    assert_eq!(yhat_of(&bt)[0], y1[0]);

    // malformed / out-of-contract requests -> 400 with an error body
    for bad in [
        "not json",
        r#"{"docs": []}"#,
        r#"{"docs": [[]]}"#,
        r#"{"docs": [[999999]]}"#, // out of vocab
        r#"{"texts": ["zzz qqq"]}"#,
    ] {
        let path = if bad.contains("texts") { "/predict/text" } else { "/predict" };
        let (s, b) = client.request("POST", path, bad).unwrap();
        assert_eq!(s, 400, "{bad} -> {b}");
        assert!(json::parse(&b).unwrap().get("error").is_some());
    }
    let (s404, _) = client.request("GET", "/nope", "").unwrap();
    assert_eq!(s404, 404);

    // hot swap to a second model while clients keep hammering: zero
    // dropped requests, versions only ever move forward
    let (path2, model2) = trained_model("rt2.bin", 2);
    assert_ne!(model.eta, model2.eta);
    let ids: Vec<usize> = (0..4).collect();
    let results = scoped_map(&ids, 4, |i, _| {
        if i == 0 {
            // the swapper
            let (s, b) = request_once(
                &addr,
                "POST",
                "/reload",
                &format!(r#"{{"path": "{}"}}"#, path2.display()),
            )
            .unwrap();
            assert_eq!(s, 200, "{b}");
            let v = json::parse(&b).unwrap();
            assert_eq!(v.get("model_version").unwrap().as_usize(), Some(2));
            Vec::new()
        } else {
            let mut c = Client::connect(&addr).unwrap();
            let mut versions = Vec::new();
            for _ in 0..20 {
                let (s, b) = c.request("POST", "/predict", req).unwrap();
                assert_eq!(s, 200, "dropped request during hot swap: {b}");
                let v = json::parse(&b).unwrap();
                versions.push(v.get("model_version").unwrap().as_usize().unwrap());
                assert!(yhat_of(&b).iter().all(|y| y.is_finite()));
            }
            versions
        }
    });
    for versions in results.iter().skip(1) {
        assert!(versions.windows(2).all(|ab| ab[0] <= ab[1]), "version went backwards");
        assert!(versions.iter().all(|&v| v == 1 || v == 2));
    }
    // after the swap, the same request routes to the new model
    let (sv, bv) = request_once(&addr, "GET", "/healthz", "").unwrap();
    assert_eq!(sv, 200);
    assert_eq!(json::parse(&bv).unwrap().get("model_version").unwrap().as_usize(), Some(2));

    // stats reflect the traffic
    let (ss, bs) = request_once(&addr, "GET", "/stats", "").unwrap();
    assert_eq!(ss, 200);
    let sv = json::parse(&bs).unwrap();
    assert!(sv.get("requests").unwrap().as_f64().unwrap() >= 60.0);
    assert!(sv.get("predict_docs").unwrap().as_f64().unwrap() >= 60.0);
    assert!(sv.get("batches").unwrap().as_f64().unwrap() >= 1.0);
    assert_eq!(sv.get("reloads").unwrap().as_usize(), Some(1));
    assert_eq!(sv.get("workers").unwrap().as_usize(), Some(2));
    assert!(sv.get("versions").unwrap().as_array().unwrap().len() >= 2);

    server.stop();
    std::fs::remove_file(path).ok();
    std::fs::remove_file(path2).ok();
}

#[test]
fn predictions_survive_server_restart() {
    // Resident state (cache, scratch) must not leak into results: a fresh
    // server on the same model file reproduces the same predictions.
    let (path, _model) = trained_model("restart.bin", 3);
    let req = r#"{"docs": [[1, 2, 3], [6, 5, 6, 5]], "seed": 42}"#;
    let run = || {
        let server = Server::start(&path, &quick_cfg()).unwrap();
        let addr = server.local_addr().to_string();
        let (s, b) = request_once(&addr, "POST", "/predict", req).unwrap();
        assert_eq!(s, 200, "{b}");
        let y = yhat_of(&b);
        server.stop();
        y
    };
    let a = run();
    let b = run();
    assert_eq!(a, b);
    std::fs::remove_file(path).ok();
}

#[test]
fn model_without_vocab_rejects_text_endpoint() {
    let spec = SyntheticSpec::continuous_small();
    let mut rng = Pcg64::seed_from_u64(5);
    let corpus = generate_corpus(&spec, &mut rng);
    let engine = EngineHandle::native();
    let out = train(&corpus, &quick_cfg(), &engine, &mut rng).unwrap();
    let path = tmp("novocab.bin");
    save_model_with_vocab(&out.model, None, &path).unwrap();
    let server = Server::start(&path, &quick_cfg()).unwrap();
    let addr = server.local_addr().to_string();
    let (s, b) = request_once(&addr, "POST", "/predict/text", r#"{"texts": ["hello"]}"#).unwrap();
    assert_eq!(s, 400);
    assert!(b.contains("vocabulary"), "{b}");
    // BoW prediction still works
    let (s, b) = request_once(&addr, "POST", "/predict", r#"{"docs": [[0, 1]]}"#).unwrap();
    assert_eq!(s, 200, "{b}");
    server.stop();
    std::fs::remove_file(path).ok();
}
