//! End-to-end observability test: boot a real server, drive scripted
//! traffic (cache hits, an error, repeated predicts), and scrape
//! `GET /metrics` over actual HTTP. Asserts the exposition is
//! line-by-line valid Prometheus text format and that the series move
//! the way the traffic says they must.
//!
//! Byte-stability of the renderer across identical states is covered
//! in-process by `obs::expo` unit tests — over HTTP each scrape
//! increments the request counters, so two scrapes are never identical.

use cfslda::config::schema::ExperimentConfig;
use cfslda::data::synthetic::{generate_corpus, SyntheticSpec};
use cfslda::data::vocab::Vocab;
use cfslda::model::persist::save_model_with_vocab;
use cfslda::runtime::EngineHandle;
use cfslda::sampler::gibbs_train::train;
use cfslda::serve::http::{request_once, Client};
use cfslda::serve::server::Server;
use cfslda::util::rng::Pcg64;
use std::io::{Read, Write};
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("cfslda_it_obs_{}_{name}", std::process::id()));
    p
}

fn quick_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::quick();
    c.train.sweeps = 15;
    c.train.burnin = 3;
    c.train.predict_sweeps = 6;
    c.train.predict_burnin = 2;
    c.serve.addr = "127.0.0.1:0".to_string();
    c.serve.workers = 2;
    c.serve.max_batch = 8;
    c.serve.max_wait_us = 200;
    c.serve.cache_capacity = 64;
    c
}

fn trained_model(name: &str, seed: u64) -> PathBuf {
    let spec = SyntheticSpec::continuous_small();
    let mut rng = Pcg64::seed_from_u64(seed);
    let corpus = generate_corpus(&spec, &mut rng);
    let engine = EngineHandle::native();
    let out = train(&corpus, &quick_cfg(), &engine, &mut rng).unwrap();
    let vocab =
        Vocab::from_terms((0..out.model.w).map(|i| format!("word{i}"))).unwrap();
    let path = tmp(name);
    save_model_with_vocab(&out.model, Some(&vocab), &path).unwrap();
    path
}

/// Value of a sample whose series part (name + optional labels) matches
/// `series` exactly, e.g. `cfslda_http_requests_total` or
/// `cfslda_request_duration_seconds_count{endpoint="predict"}`.
fn sample(body: &str, series: &str) -> f64 {
    for line in body.lines() {
        if let Some((s, v)) = line.rsplit_once(' ') {
            if s == series {
                return v.parse().unwrap_or_else(|_| panic!("bad value in {line:?}"));
            }
        }
    }
    panic!("series {series:?} not found in exposition");
}

/// Every line is either a `# HELP`/`# TYPE` comment or `series value`
/// with a float-parseable value, and every `# TYPE` names a known kind.
fn assert_valid_exposition(body: &str) {
    assert!(!body.is_empty());
    let mut samples = 0usize;
    for line in body.lines() {
        if let Some(rest) = line.strip_prefix("# ") {
            assert!(
                rest.starts_with("HELP ") || rest.starts_with("TYPE "),
                "unknown comment {line:?}"
            );
            if let Some(t) = rest.strip_prefix("TYPE ") {
                let kind = t.rsplit(' ').next().unwrap();
                assert!(
                    matches!(kind, "counter" | "gauge" | "histogram"),
                    "unknown metric type in {line:?}"
                );
            }
            continue;
        }
        let (series, value) = line.rsplit_once(' ').expect("space-separated sample");
        assert!(series.starts_with("cfslda_"), "foreign series {line:?}");
        let v: f64 = value.parse().unwrap_or_else(|_| panic!("bad value in {line:?}"));
        assert!(v >= 0.0, "negative sample in {line:?}");
        samples += 1;
    }
    assert!(samples > 20, "suspiciously small exposition ({samples} samples)");
}

/// Cumulative histogram buckets must be non-decreasing in `le` order and
/// the `+Inf` bucket must equal `_count`.
fn assert_histogram_shape(body: &str, name: &str, label: &str) {
    let prefix = if label.is_empty() {
        format!("{name}_bucket{{le=\"")
    } else {
        format!("{name}_bucket{{{label},le=\"")
    };
    let mut last = 0.0f64;
    let mut inf = f64::NAN;
    let mut seen = 0usize;
    for line in body.lines() {
        if let Some(rest) = line.strip_prefix(&prefix) {
            let (_, v) = line.rsplit_once(' ').unwrap();
            let c: f64 = v.parse().unwrap();
            assert!(c >= last, "non-monotonic cumulative bucket {line:?}");
            last = c;
            seen += 1;
            if rest.starts_with("+Inf") {
                inf = c;
            }
        }
    }
    assert!(seen > 1, "no buckets found for {prefix:?}");
    let count_series = if label.is_empty() {
        format!("{name}_count")
    } else {
        format!("{name}_count{{{label}}}")
    };
    assert_eq!(inf, sample(body, &count_series), "{count_series} != +Inf bucket");
}

#[test]
fn metrics_endpoint_is_valid_and_series_move_under_load() {
    let path = trained_model("metrics.bin", 11);
    let server = Server::start(&path, &quick_cfg()).unwrap();
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();

    // Scripted traffic: 3 predicts (one repeated -> cache hits), one
    // malformed request (-> errors counter), one healthz.
    let req = r#"{"docs": [[0, 1, 2, 3], [4, 5]], "seed": 9}"#;
    for _ in 0..2 {
        let (s, b) = client.request("POST", "/predict", req).unwrap();
        assert_eq!(s, 200, "{b}");
    }
    let (s, _) = client.request("POST", "/predict", r#"{"docs": [[5, 5, 5]], "seed": 1}"#).unwrap();
    assert_eq!(s, 200);
    let (s, _) = client.request("POST", "/predict", "not json").unwrap();
    assert_eq!(s, 400);
    let (s, _) = client.request("GET", "/healthz", "").unwrap();
    assert_eq!(s, 200);

    let (s1, scrape1) = client.request("GET", "/metrics", "").unwrap();
    assert_eq!(s1, 200, "{scrape1}");
    assert_valid_exposition(&scrape1);

    // Traffic-driven minimums. The training counters are process-global
    // and other tests may run concurrently, so serve-side counters (which
    // belong to this server instance alone) get exact lower bounds.
    assert!(sample(&scrape1, "cfslda_http_requests_total") >= 6.0);
    assert!(sample(&scrape1, "cfslda_http_errors_total") >= 1.0);
    assert!(sample(&scrape1, "cfslda_predict_docs_total") >= 5.0);
    assert!(sample(&scrape1, "cfslda_predict_batches_total") >= 1.0);
    // Second identical request was served from the LRU cache.
    assert!(sample(&scrape1, "cfslda_cache_hits_total") >= 2.0);
    assert!(sample(&scrape1, "cfslda_cache_misses_total") >= 2.0);
    // Latency histograms are on by default: every predict observed once.
    assert!(
        sample(&scrape1, "cfslda_request_duration_seconds_count{endpoint=\"predict\"}") >= 4.0
    );
    assert!(
        sample(&scrape1, "cfslda_request_duration_seconds_sum{endpoint=\"predict\"}") > 0.0
    );
    // This process trained a model in `trained_model`, so the global
    // training registry has moved.
    assert!(sample(&scrape1, "cfslda_train_sweeps_total") >= 15.0);
    assert!(sample(&scrape1, "cfslda_train_tokens_total") > 0.0);

    assert_histogram_shape(&scrape1, "cfslda_request_duration_seconds", "endpoint=\"predict\"");
    assert_histogram_shape(&scrape1, "cfslda_batch_wait_seconds", "");

    // A second scrape strictly advances the request counter (the first
    // scrape itself was a request) and observes the scrape latency.
    let (s2, scrape2) = client.request("GET", "/metrics", "").unwrap();
    assert_eq!(s2, 200);
    assert_valid_exposition(&scrape2);
    assert!(
        sample(&scrape2, "cfslda_http_requests_total")
            > sample(&scrape1, "cfslda_http_requests_total")
    );
    assert!(
        sample(&scrape2, "cfslda_request_duration_seconds_count{endpoint=\"metrics\"}")
            >= 1.0
    );

    // `/stats` still serves the legacy JSON view off the same counters.
    let (ss, bs) = client.request("GET", "/stats", "").unwrap();
    assert_eq!(ss, 200);
    let v = cfslda::config::json::parse(&bs).unwrap();
    assert!(v.get("requests").unwrap().as_f64().unwrap() >= 7.0);

    server.stop();
    std::fs::remove_file(path).ok();
}

#[test]
fn metrics_endpoint_uses_prometheus_content_type() {
    let path = trained_model("ctype.bin", 12);
    let server = Server::start(&path, &quick_cfg()).unwrap();
    let addr = server.local_addr();

    // The test client discards headers, so speak raw HTTP for this one.
    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    stream
        .write_all(b"GET /metrics HTTP/1.1\r\nHost: cfslda\r\nConnection: close\r\n\r\n")
        .unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    let (head, body) = raw.split_once("\r\n\r\n").expect("header/body split");
    assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
    assert!(
        head.contains("Content-Type: text/plain; version=0.0.4"),
        "missing Prometheus content type: {head}"
    );
    assert_valid_exposition(body);

    server.stop();
    std::fs::remove_file(path).ok();
}

/// Connection-level telemetry on the epoll backend: an open keep-alive
/// connection shows in the gauge, accepts/sheds count, and the event-loop
/// iteration histogram observes real batches. Scraped over the event loop
/// itself.
#[test]
fn epoll_scrape_reports_connection_and_event_loop_series() {
    let path = trained_model("epollobs.bin", 14);
    let mut cfg = quick_cfg();
    cfg.serve.backend = cfslda::config::schema::ServeBackend::Epoll;
    cfg.serve.max_conns = 2;
    let server = Server::start(&path, &cfg).unwrap();
    let addr = server.local_addr().to_string();

    let mut client = Client::connect(&addr).unwrap();
    let (s, b) = client.request("POST", "/predict", r#"{"docs": [[0, 1, 2]], "seed": 4}"#).unwrap();
    assert_eq!(s, 200, "{b}");
    // a second connection fills the admission limit; a third is shed
    let mut c2 = Client::connect(&addr).unwrap();
    assert_eq!(c2.request("GET", "/healthz", "").unwrap().0, 200);
    {
        let mut shed = std::net::TcpStream::connect(&addr).unwrap();
        shed.set_read_timeout(Some(std::time::Duration::from_secs(20))).unwrap();
        let mut raw = Vec::new();
        shed.read_to_end(&mut raw).unwrap();
        assert!(raw.starts_with(b"HTTP/1.1 503"), "{}", String::from_utf8_lossy(&raw));
    }

    let (s, scrape) = client.request("GET", "/metrics", "").unwrap();
    assert_eq!(s, 200);
    assert_valid_exposition(&scrape);
    // this client + c2 are open right now; the shed one never registered
    assert_eq!(sample(&scrape, "cfslda_open_connections"), 2.0);
    assert!(sample(&scrape, "cfslda_accepted_total") >= 3.0);
    assert_eq!(sample(&scrape, "cfslda_shed_total"), 1.0);
    // the reactor observed at least the batches carrying this traffic
    assert!(sample(&scrape, "cfslda_event_loop_iteration_seconds_count") >= 3.0);
    assert!(sample(&scrape, "cfslda_event_loop_iteration_seconds_sum") >= 0.0);
    assert_histogram_shape(&scrape, "cfslda_event_loop_iteration_seconds", "");
    // request-path series move exactly as on the threads backend
    assert!(sample(&scrape, "cfslda_http_requests_total") >= 3.0);
    assert!(
        sample(&scrape, "cfslda_request_duration_seconds_count{endpoint=\"predict\"}") >= 1.0
    );

    drop(c2);
    server.stop();
    std::fs::remove_file(path).ok();
}

#[test]
fn latency_histograms_can_be_disabled() {
    let path = trained_model("nolat.bin", 13);
    let mut cfg = quick_cfg();
    cfg.obs.latency_histograms = false;
    let server = Server::start(&path, &cfg).unwrap();
    let addr = server.local_addr().to_string();

    let (s, b) =
        request_once(&addr, "POST", "/predict", r#"{"docs": [[0, 1]], "seed": 3}"#).unwrap();
    assert_eq!(s, 200, "{b}");
    let (s, scrape) = request_once(&addr, "GET", "/metrics", "").unwrap();
    assert_eq!(s, 200);
    assert_valid_exposition(&scrape);
    // Counters still move; the per-endpoint duration histograms do not.
    assert!(sample(&scrape, "cfslda_http_requests_total") >= 2.0);
    assert_eq!(
        sample(&scrape, "cfslda_request_duration_seconds_count{endpoint=\"predict\"}"),
        0.0
    );

    server.stop();
    std::fs::remove_file(path).ok();
}
