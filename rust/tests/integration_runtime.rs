//! Cross-engine integration: the AOT XLA artifacts must agree with the
//! pure-rust native engine on random inputs — including padding edges and
//! the chunked (> row bucket) gram path.
//!
//! Requires `make artifacts` (skips with a loud message otherwise, so
//! `cargo test` works on a fresh checkout).

use cfslda::runtime::native::NativeEngine;
use cfslda::runtime::{EngineHandle, EngineImpl};
use cfslda::util::rng::Pcg64;
use std::path::Path;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::env::var("CFSLDA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let p = Path::new(&dir).to_path_buf();
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: no artifacts at {p:?} — run `make artifacts` first");
        None
    }
}

fn engines() -> Option<(EngineHandle, NativeEngine)> {
    let dir = artifacts_dir()?;
    Some((EngineHandle::xla(&dir).expect("xla engine"), NativeEngine::new()))
}

fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[test]
fn eta_solve_agrees_across_engines() {
    let Some((xla, native)) = engines() else { return };
    let mut rng = Pcg64::seed_from_u64(101);
    for &(d, t) in &[(1usize, 2usize), (17, 5), (300, 8), (1000, 16), (4096, 8)] {
        let zbar: Vec<f32> = (0..d * t).map(|_| rng.next_f32()).collect();
        let y: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let (e1, m1) = xla.eta_solve(&zbar, &y, t, 0.7, 0.2).unwrap();
        let (e2, m2) = native.eta_solve(&zbar, &y, t, 0.7, 0.2).unwrap();
        assert_eq!(e1.len(), t);
        for (a, b) in e1.iter().zip(&e2) {
            assert!(close(*a, *b, 1e-3), "d={d} t={t}: {e1:?} vs {e2:?}");
        }
        assert!(close(m1, m2, 1e-3), "mse {m1} vs {m2}");
    }
}

#[test]
fn eta_solve_chunked_path_agrees() {
    let Some((xla, native)) = engines() else { return };
    let mut rng = Pcg64::seed_from_u64(202);
    // 9000 rows crosses two chunk boundaries (bucket 4096).
    let (d, t) = (9000usize, 16usize);
    let zbar: Vec<f32> = (0..d * t).map(|_| rng.next_f32()).collect();
    let y: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
    let (e1, m1) = xla.eta_solve(&zbar, &y, t, 0.3, 0.0).unwrap();
    let (e2, m2) = native.eta_solve(&zbar, &y, t, 0.3, 0.0).unwrap();
    for (a, b) in e1.iter().zip(&e2) {
        assert!(close(*a, *b, 1e-3), "{e1:?} vs {e2:?}");
    }
    assert!(close(m1, m2, 1e-3));
}

#[test]
fn predict_agrees_and_handles_no_labels() {
    let Some((xla, native)) = engines() else { return };
    let mut rng = Pcg64::seed_from_u64(303);
    for &(b, t) in &[(1usize, 2usize), (100, 8), (5000, 32)] {
        let zbar: Vec<f32> = (0..b * t).map(|_| rng.next_f32()).collect();
        let eta: Vec<f64> = (0..t).map(|_| rng.next_gaussian()).collect();
        let y: Vec<f64> = (0..b).map(|_| rng.next_gaussian()).collect();
        let p1 = xla.predict(&zbar, &eta, Some(&y), t).unwrap();
        let p2 = native.predict(&zbar, &eta, Some(&y), t).unwrap();
        assert_eq!(p1.yhat.len(), b);
        for (a, c) in p1.yhat.iter().zip(&p2.yhat) {
            assert!(close(*a, *c, 1e-4), "b={b} t={t}");
        }
        assert!(close(p1.mse, p2.mse, 1e-3));
        assert!(close(p1.acc, p2.acc, 1e-6), "acc {} vs {}", p1.acc, p2.acc);
        // no labels -> metrics zero, yhat same
        let p3 = xla.predict(&zbar, &eta, None, t).unwrap();
        assert_eq!(p3.mse, 0.0);
        for (a, c) in p3.yhat.iter().zip(&p1.yhat) {
            assert!(close(*a, *c, 1e-6));
        }
    }
}

#[test]
fn combine_agrees_including_padded_shards() {
    let Some((xla, native)) = engines() else { return };
    let mut rng = Pcg64::seed_from_u64(404);
    for &(m, b) in &[(1usize, 10usize), (4, 1216), (16, 5000)] {
        let preds: Vec<Vec<f64>> =
            (0..m).map(|_| (0..b).map(|_| rng.next_gaussian()).collect()).collect();
        let weights: Vec<f64> = (0..m).map(|_| rng.next_f64() + 0.05).collect();
        let c1 = xla.combine(&preds, &weights).unwrap();
        let c2 = native.combine(&preds, &weights).unwrap();
        assert_eq!(c1.len(), b);
        for (a, d) in c1.iter().zip(&c2) {
            assert!(close(*a, *d, 1e-4), "m={m} b={b}");
        }
    }
    // more shards than the bucket must error cleanly
    let preds: Vec<Vec<f64>> = (0..17).map(|_| vec![0.0; 4]).collect();
    assert!(xla.combine(&preds, &vec![1.0; 17]).is_err());
}

#[test]
fn loglik_agrees() {
    let Some((xla, native)) = engines() else { return };
    let mut rng = Pcg64::seed_from_u64(505);
    let (b, t) = (700usize, 8usize);
    let y: Vec<f64> = (0..b).map(|_| rng.next_gaussian()).collect();
    let mu: Vec<f32> = (0..b * t).map(|_| rng.next_f32()).collect();
    let l1 = xla.loglik(&y, &mu, t, 0.6).unwrap();
    let l2 = native.loglik(&y, &mu, t, 0.6).unwrap();
    assert_eq!(l1.len(), b * t);
    for (a, c) in l1.iter().zip(&l2) {
        assert!((a - c).abs() < 1e-3, "{a} vs {c}");
    }
}

#[test]
fn topic_bucket_rounding_is_transparent() {
    // t = 5 pads into the T = 8 bucket; results must match native exactly
    // (padding topics carry zero mass).
    let Some((xla, native)) = engines() else { return };
    let mut rng = Pcg64::seed_from_u64(606);
    let (d, t) = (64usize, 5usize);
    let zbar: Vec<f32> = (0..d * t).map(|_| rng.next_f32()).collect();
    let y: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
    let (e1, _) = xla.eta_solve(&zbar, &y, t, 1.0, 0.0).unwrap();
    let (e2, _) = native.eta_solve(&zbar, &y, t, 1.0, 0.0).unwrap();
    assert_eq!(e1.len(), t);
    for (a, b) in e1.iter().zip(&e2) {
        assert!(close(*a, *b, 1e-3));
    }
}

#[test]
fn service_handle_is_shareable_across_threads() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = EngineHandle::xla(&dir).unwrap();
    let mut rng = Pcg64::seed_from_u64(707);
    let t = 8usize;
    let zbar: Vec<f32> = (0..50 * t).map(|_| rng.next_f32()).collect();
    let y: Vec<f64> = (0..50).map(|_| rng.next_gaussian()).collect();
    let (eta_ref, _) = engine.eta_solve(&zbar, &y, t, 0.5, 0.0).unwrap();
    std::thread::scope(|s| {
        for _ in 0..4 {
            let engine = engine.clone();
            let (zbar, y, eta_ref) = (&zbar, &y, &eta_ref);
            s.spawn(move || {
                for _ in 0..3 {
                    let (eta, _) = engine.eta_solve(zbar, y, t, 0.5, 0.0).unwrap();
                    assert_eq!(&eta, eta_ref); // same inputs -> same outputs
                }
            });
        }
    });
}
