//! End-to-end training integration: the full pipeline learns, converges
//! and beats baselines on synthetic corpora whose ground truth is known.

use cfslda::config::schema::{EngineKind, ExperimentConfig, ResponseKind};
use cfslda::data::synthetic::{generate_split, generate_with_truth, SyntheticSpec};
use cfslda::eval::mode_diag::align_topics;
use cfslda::model::slda::SldaModel;
use cfslda::runtime::EngineHandle;
use cfslda::sampler::{gibbs_predict, gibbs_train};
use cfslda::util::rng::Pcg64;
use cfslda::util::stats::Summary;

fn cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::quick();
    c.engine = EngineKind::Native;
    c.train.sweeps = 30;
    c.train.burnin = 5;
    c.train.eta_every = 5;
    c.train.predict_sweeps = 12;
    c.train.predict_burnin = 4;
    c
}

#[test]
fn learns_topics_close_to_ground_truth() {
    // Train on a corpus drawn from the model family; the learned phi must
    // align (post-Hungarian) with the generating phi much better than a
    // random topic set does.
    let mut spec = SyntheticSpec::continuous_small();
    spec.docs = 400;
    spec.beta = 0.02;
    let mut rng = Pcg64::seed_from_u64(1);
    let (corpus, truth) = generate_with_truth(&spec, &mut rng);
    let engine = EngineHandle::native();
    let out = gibbs_train::train(&corpus, &cfg(), &engine, &mut rng).unwrap();

    let learned = out.model.phi_topic_rows();
    let report = align_topics(&learned, &truth.phi);
    let random: Vec<Vec<f64>> =
        (0..spec.topics).map(|_| rng.next_dirichlet_sym(0.05, spec.vocab)).collect();
    let random_report = align_topics(&random, &truth.phi);
    assert!(
        report.aligned_distance < 0.6 * random_report.aligned_distance,
        "learned TV {} should beat random TV {}",
        report.aligned_distance,
        random_report.aligned_distance
    );
}

#[test]
fn training_mse_trajectory_decreases() {
    let spec = SyntheticSpec::continuous_small();
    let mut rng = Pcg64::seed_from_u64(2);
    let ds = generate_split(&spec, 180, &mut rng);
    let engine = EngineHandle::native();
    let out = gibbs_train::train(&ds.train, &cfg(), &engine, &mut rng).unwrap();
    let mses: Vec<f64> = out.history.iter().map(|h| h.train_mse).collect();
    assert!(mses.len() >= 3);
    assert!(
        mses.last().unwrap() < &(0.8 * mses[0]),
        "training MSE did not decrease: {mses:?}"
    );
}

#[test]
fn continuous_end_to_end_quality() {
    let spec = SyntheticSpec::continuous_small();
    let mut rng = Pcg64::seed_from_u64(3);
    let ds = generate_split(&spec, 180, &mut rng);
    let engine = EngineHandle::native();
    let out = gibbs_train::train(&ds.train, &cfg(), &engine, &mut rng).unwrap();
    let ys = ds.test.responses();
    let (pred, _) = gibbs_predict::predict_corpus(
        &out.model, &ds.test, &cfg().train, &engine, Some(&ys), &mut rng,
    )
    .unwrap();
    let var = Summary::from_slice(&ys).var();
    assert!(pred.mse < 0.6 * var, "test mse {} vs label variance {var}", pred.mse);
}

#[test]
fn binary_end_to_end_quality() {
    let mut spec = SyntheticSpec::binary_small();
    spec.docs = 400;
    let mut rng = Pcg64::seed_from_u64(4);
    let ds = generate_split(&spec, 300, &mut rng);
    let mut c = cfg();
    c.response = ResponseKind::Binary;
    let engine = EngineHandle::native();
    let out = gibbs_train::train(&ds.train, &c, &engine, &mut rng).unwrap();
    let ys = ds.test.responses();
    let (pred, _) = gibbs_predict::predict_corpus(
        &out.model, &ds.test, &c.train, &engine, Some(&ys), &mut rng,
    )
    .unwrap();
    // majority-class baseline
    let pos = ys.iter().filter(|&&y| y > 0.5).count() as f64 / ys.len() as f64;
    let majority = pos.max(1.0 - pos);
    assert!(
        pred.acc > majority.min(0.95) - 0.02,
        "accuracy {} should at least approach majority baseline {majority}",
        pred.acc
    );
}

#[test]
fn xla_engine_trains_equivalently_when_available() {
    // The whole training loop with the XLA engine: same seed => eta within
    // float32 tolerance of the native run (sampling uses the same RNG
    // stream; only the eta solve differs numerically).
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let spec = SyntheticSpec::continuous_small();
    let engine_n = EngineHandle::native();
    let engine_x = EngineHandle::xla(dir).unwrap();
    let run = |engine: &EngineHandle| {
        let mut rng = Pcg64::seed_from_u64(5);
        let ds = generate_split(&spec, 180, &mut rng);
        let mut c = cfg();
        c.train.sweeps = 10;
        c.train.burnin = 9; // single eta solve at the end -> identical z path
        c.train.eta_every = 1;
        gibbs_train::train(&ds.train, &c, engine, &mut rng).unwrap().model
    };
    let mn: SldaModel = run(&engine_n);
    let mx: SldaModel = run(&engine_x);
    for (a, b) in mn.eta.iter().zip(&mx.eta) {
        assert!((a - b).abs() < 1e-3, "native {:?} vs xla {:?}", mn.eta, mx.eta);
    }
    assert_eq!(mn.phi, mx.phi, "phi depends only on counts and must be identical");
}
