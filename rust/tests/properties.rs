//! Property-based tests over the system's core invariants (testkit-driven;
//! every failure message carries a replay seed).

use cfslda::config::schema::{ExperimentConfig, KernelKind};
use cfslda::data::corpus::{Corpus, Document};
use cfslda::data::partition::{random_shards, train_test_split};
use cfslda::data::synthetic::{generate_with_truth, SyntheticSpec};
use cfslda::model::counts::CountMatrices;
use cfslda::regress::ridge;
use cfslda::runtime::native::NativeEngine;
use cfslda::runtime::pad;
use cfslda::runtime::{EngineHandle, EngineImpl};
use cfslda::sampler::gibbs_predict::infer_zbar_with_kernel;
use cfslda::sampler::gibbs_train::train;
use cfslda::testkit::{f64_in, forall, usize_in, vec_f32, vec_f64};
use cfslda::util::rng::Pcg64;

#[test]
fn partition_covers_each_doc_exactly_once() {
    forall(
        "partition-exactly-once",
        40,
        |rng| (usize_in(rng, 1, 500), usize_in(rng, 1, 16), rng.next_u64()),
        |&(n, m, seed)| {
            let shards = random_shards(n, m, &mut Pcg64::seed_from_u64(seed));
            assert_eq!(shards.len(), m);
            let mut seen: Vec<usize> = shards.iter().flatten().copied().collect();
            seen.sort_unstable();
            assert_eq!(seen, (0..n).collect::<Vec<_>>());
            let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 1, "unbalanced shards {sizes:?}");
        },
    );
}

#[test]
fn split_preserves_multiset_of_docs() {
    forall(
        "split-multiset",
        25,
        |rng| (usize_in(rng, 1, 200), rng.next_u64()),
        |&(n, seed)| {
            let mut rng = Pcg64::seed_from_u64(seed);
            let corpus = Corpus::new(
                (0..n)
                    .map(|i| Document { tokens: vec![(i % 7) as u32], response: i as f64 })
                    .collect(),
                7,
            );
            let k = rng.gen_range(n + 1);
            let ds = train_test_split(&corpus, k, &mut rng);
            let mut all: Vec<i64> = ds
                .train
                .responses
                .iter()
                .chain(&ds.test.responses)
                .map(|&y| y as i64)
                .collect();
            all.sort_unstable();
            assert_eq!(all, (0..n as i64).collect::<Vec<_>>());
        },
    );
}

#[test]
fn gibbs_style_count_updates_preserve_invariants() {
    forall(
        "count-invariants",
        25,
        |rng| {
            let d = usize_in(rng, 1, 8);
            let t = usize_in(rng, 2, 16);
            let w = usize_in(rng, 2, 30);
            (d, t, w, rng.next_u64())
        },
        |&(d, t, w, seed)| {
            let mut rng = Pcg64::seed_from_u64(seed);
            let mut counts = CountMatrices::new(d, t, w);
            let mut tokens = Vec::new();
            for di in 0..d {
                for _ in 0..usize_in(&mut rng, 1, 40) {
                    let wi = rng.gen_range(w) as u32;
                    let ti = rng.gen_range(t);
                    counts.inc(di, wi, ti);
                    tokens.push((di, wi, ti));
                }
            }
            let total = tokens.len() as u64;
            for _ in 0..200 {
                let i = rng.gen_range(tokens.len());
                let (di, wi, old) = tokens[i];
                counts.dec(di, wi, old);
                let new = rng.gen_range(t);
                counts.inc(di, wi, new);
                tokens[i] = (di, wi, new);
            }
            counts.check_invariants().unwrap();
            assert_eq!(counts.total_tokens(), total);
        },
    );
}

/// Seed-exact equivalence of the two Gibbs kernels (DESIGN.md §Perf): for
/// the same `Pcg64` stream, training must produce byte-identical topic
/// assignments, counts and regression coefficients, and prediction must
/// produce a byte-identical zbar — the sparse bucket decomposition only
/// skips exact-zero terms, it never changes the arithmetic. Supervised
/// sweeps are pinned to `resp_mode = exact`: under the default `auto` the
/// sparse kernel's eta-active phase runs its own MH chain (a different,
/// statistically equivalent RNG sequence — `tests/resp_equivalence.rs`).
#[test]
fn sparse_and_dense_kernels_are_seed_exact_identical() {
    use cfslda::config::schema::RespMode;
    let spec = SyntheticSpec::continuous_small();
    for &topics in &[8usize, 17] {
        let run = |kernel: KernelKind| {
            let mut rng = Pcg64::seed_from_u64(4242);
            let (corpus, _) = generate_with_truth(&spec, &mut rng);
            let mut cfg = ExperimentConfig::quick();
            cfg.model.topics = topics;
            cfg.train.sweeps = 12; // 4 burn-in (LDA path) + 8 eta-active
            cfg.train.burnin = 4;
            cfg.train.eta_every = 4;
            cfg.sampler.kernel = kernel;
            cfg.sampler.resp_mode = RespMode::Exact;
            let engine = EngineHandle::native();
            let out = train(&corpus, &cfg, &engine, &mut rng).unwrap();
            out.counts.check_invariants().unwrap();
            let zbar =
                infer_zbar_with_kernel(&out.model, &corpus, &cfg.train, kernel, &mut rng);
            (out, zbar)
        };
        let (a, za) = run(KernelKind::Dense);
        let (b, zb) = run(KernelKind::Sparse);
        assert_eq!(a.z, b.z, "z assignments diverged at T={topics}");
        assert_eq!(a.counts.ndt, b.counts.ndt, "ndt diverged at T={topics}");
        assert_eq!(a.model.eta, b.model.eta, "eta diverged at T={topics}");
        assert_eq!(za, zb, "prediction zbar diverged at T={topics}");
    }
}

/// The sparse non-zero lists must track `ndt`/`ntw` exactly through
/// arbitrary inc/dec churn (the Gibbs inner operation).
#[test]
fn sparse_index_consistent_after_random_sweeps() {
    forall(
        "sparse-index-consistency",
        25,
        |rng| {
            let d = usize_in(rng, 1, 8);
            let t = usize_in(rng, 2, 16);
            let w = usize_in(rng, 2, 30);
            (d, t, w, rng.next_u64())
        },
        |&(d, t, w, seed)| {
            let mut rng = Pcg64::seed_from_u64(seed);
            let mut counts = CountMatrices::new(d, t, w);
            let mut tokens = Vec::new();
            for di in 0..d {
                for _ in 0..usize_in(&mut rng, 1, 40) {
                    let wi = rng.gen_range(w) as u32;
                    let ti = rng.gen_range(t);
                    counts.inc(di, wi, ti);
                    tokens.push((di, wi, ti));
                }
            }
            counts.enable_sparse_index();
            for _ in 0..300 {
                let i = rng.gen_range(tokens.len());
                let (di, wi, old) = tokens[i];
                counts.dec(di, wi, old);
                let new = rng.gen_range(t);
                counts.inc(di, wi, new);
                tokens[i] = (di, wi, new);
            }
            // check_invariants validates the lists against the counts...
            counts.check_invariants().unwrap();
            // ...and an explicit recomputation double-checks the checker.
            let nz = counts.nz.as_ref().unwrap();
            for di in 0..d {
                let want: Vec<u16> = (0..t)
                    .filter(|&ti| counts.ndt[di * t + ti] > 0)
                    .map(|ti| ti as u16)
                    .collect();
                assert_eq!(nz.doc_nz[di], want, "doc {di} list mismatch");
            }
            for wi in 0..w {
                let want: Vec<u16> = (0..t)
                    .filter(|&ti| counts.ntw[wi * t + ti] > 0)
                    .map(|ti| ti as u16)
                    .collect();
                assert_eq!(nz.word_nz[wi], want, "word {wi} list mismatch");
            }
        },
    );
}

#[test]
fn combiner_weights_normalize_and_uniform_equals_mean() {
    forall(
        "combine-normalization",
        30,
        |rng| {
            let m = usize_in(rng, 1, 16);
            let b = usize_in(rng, 1, 64);
            let preds: Vec<Vec<f64>> = (0..m).map(|_| vec_f64(rng, b, -3.0, 3.0)).collect();
            let weights = vec_f64(rng, m, 0.01, 5.0);
            (preds, weights)
        },
        |(preds, weights)| {
            let e = NativeEngine::new();
            let out = e.combine(preds, weights).unwrap();
            // scaling all weights must not change the output
            let scaled: Vec<f64> = weights.iter().map(|w| w * 42.0).collect();
            let out2 = e.combine(preds, &scaled).unwrap();
            for (a, b) in out.iter().zip(&out2) {
                assert!((a - b).abs() < 1e-9);
            }
            // uniform weights == arithmetic mean
            let uni = vec![1.0; preds.len()];
            let mean = e.combine(preds, &uni).unwrap();
            for (j, v) in mean.iter().enumerate() {
                let want: f64 =
                    preds.iter().map(|p| p[j]).sum::<f64>() / preds.len() as f64;
                assert!((v - want).abs() < 1e-9);
            }
        },
    );
}

#[test]
fn ridge_solution_satisfies_normal_equations() {
    forall(
        "ridge-normal-equations",
        25,
        |rng| {
            let d = usize_in(rng, 3, 60);
            let t = usize_in(rng, 1, 12);
            let zbar = vec_f32(rng, d * t, 0.0, 1.0);
            let y = vec_f64(rng, d, -2.0, 2.0);
            let lambda = f64_in(rng, 0.01, 5.0);
            let mu = f64_in(rng, -1.0, 1.0);
            (zbar, y, lambda, mu, t)
        },
        |(zbar, y, lambda, mu, t)| {
            let t = *t;
            let w = vec![1.0f64; y.len()];
            let (eta, _) = ridge::ridge_fit(zbar, y, &w, t, *lambda, *mu).unwrap();
            // residual of (G + lambda I) eta - (b + lambda mu) must be ~0
            let (g, b, _) = ridge::gram_moments(zbar, y, &w, t);
            for i in 0..t {
                let mut lhs = lambda * eta[i];
                for j in 0..t {
                    lhs += g[i * t + j] * eta[j];
                }
                let rhs = b[i] + lambda * mu;
                assert!(
                    (lhs - rhs).abs() < 1e-6 * (1.0 + rhs.abs()),
                    "normal equation row {i}: {lhs} vs {rhs}"
                );
            }
        },
    );
}

#[test]
fn padding_roundtrips_and_is_inert() {
    forall(
        "padding-roundtrip",
        30,
        |rng| {
            let rows = usize_in(rng, 1, 20);
            let cols = usize_in(rng, 1, 10);
            let rp = rows + usize_in(rng, 0, 8);
            let cp = cols + usize_in(rng, 0, 8);
            (vec_f32(rng, rows * cols, -1.0, 1.0), rows, cols, rp, cp)
        },
        |(data, rows, cols, rp, cp)| {
            let padded = pad::pad_matrix(data, *rows, *cols, *rp, *cp);
            assert_eq!(padded.len(), rp * cp);
            // original block recovers exactly
            for r in 0..*rows {
                for c in 0..*cols {
                    assert_eq!(padded[r * cp + c], data[r * cols + c]);
                }
            }
            // padding area is zero
            for r in 0..*rp {
                for c in 0..*cp {
                    if r >= *rows || c >= *cols {
                        assert_eq!(padded[r * cp + c], 0.0);
                    }
                }
            }
        },
    );
}

#[test]
fn native_predict_is_linear_in_eta() {
    forall(
        "predict-linearity",
        25,
        |rng| {
            let b = usize_in(rng, 1, 40);
            let t = usize_in(rng, 1, 10);
            let zbar = vec_f32(rng, b * t, 0.0, 1.0);
            (zbar, vec_f64(rng, t, -2.0, 2.0), vec_f64(rng, t, -2.0, 2.0), t)
        },
        |(zbar, e1, e2, t)| {
            let eng = NativeEngine::new();
            let p1 = eng.predict(zbar, e1, None, *t).unwrap().yhat;
            let p2 = eng.predict(zbar, e2, None, *t).unwrap().yhat;
            let sum: Vec<f64> = e1.iter().zip(e2).map(|(a, b)| a + b).collect();
            let ps = eng.predict(zbar, &sum, None, *t).unwrap().yhat;
            for i in 0..p1.len() {
                assert!((ps[i] - (p1[i] + p2[i])).abs() < 1e-9);
            }
        },
    );
}

#[test]
fn json_roundtrip_of_random_values() {
    use cfslda::config::json::{parse, to_string, to_string_pretty, Value};
    forall(
        "json-roundtrip",
        40,
        |rng| random_json(rng, 3),
        |v| {
            let compact = to_string(v);
            let pretty = to_string_pretty(v);
            assert_eq!(&parse(&compact).unwrap(), v);
            assert_eq!(&parse(&pretty).unwrap(), v);
        },
    );

    fn random_json(rng: &mut Pcg64, depth: usize) -> Value {
        let pick = if depth == 0 { rng.gen_range(4) } else { rng.gen_range(6) };
        match pick {
            0 => Value::Null,
            1 => Value::Bool(rng.next_f64() < 0.5),
            2 => Value::Number((rng.next_f64() * 2000.0 - 1000.0).round() / 8.0),
            3 => {
                let n = rng.gen_range(8);
                Value::String((0..n).map(|_| "ab\"\\\nπ😀 ".chars().nth(rng.gen_range(8)).unwrap()).collect())
            }
            4 => {
                let n = rng.gen_range(4);
                Value::Array((0..n).map(|_| random_json(rng, depth - 1)).collect())
            }
            _ => {
                let n = rng.gen_range(4);
                Value::Object(
                    (0..n)
                        .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                        .collect(),
                )
            }
        }
    }
}
