//! Data-pipeline integration: text -> tokens -> vocab -> corpus -> disk ->
//! training, end to end, plus failure-injection on malformed inputs.

use cfslda::config::schema::{EngineKind, ExperimentConfig};
use cfslda::data::loader;
use cfslda::data::partition::train_test_split;
use cfslda::data::synthetic::{generate_corpus, SyntheticSpec};
use cfslda::data::tokenizer::TokenizerConfig;
use cfslda::parallel::leader::{run_with_engine, Algorithm};
use cfslda::runtime::EngineHandle;
use cfslda::util::rng::Pcg64;
use std::io::Write;
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("cfslda_it_{}_{name}", std::process::id()));
    p
}

#[test]
fn synthetic_to_disk_to_training() {
    // generate -> save bow -> load -> train/predict: the full data path.
    let spec = SyntheticSpec::continuous_small();
    let mut rng = Pcg64::seed_from_u64(1);
    let corpus = generate_corpus(&spec, &mut rng);
    let path = tmp("roundtrip.bow");
    loader::save_bow(&corpus, &path).unwrap();
    let loaded = loader::load_bow(&path).unwrap();
    assert_eq!(loaded.num_docs(), corpus.num_docs());
    assert_eq!(loaded.num_tokens(), corpus.num_tokens());

    let ds = train_test_split(&loaded, 180, &mut rng);
    let mut cfg = ExperimentConfig::quick();
    cfg.engine = EngineKind::Native;
    cfg.train.sweeps = 10;
    cfg.train.burnin = 2;
    let engine = EngineHandle::native();
    let (out, _) = run_with_engine(Algorithm::SimpleAverage, &ds, &cfg, &engine, false).unwrap();
    assert!(out.test_metrics.mse.is_finite());
    std::fs::remove_file(path).ok();
}

#[test]
fn raw_text_pipeline_trains() {
    // A tiny raw-text corpus exercising tokenizer + vocab pruning + JSONL.
    let path = tmp("text.jsonl");
    let mut f = std::fs::File::create(&path).unwrap();
    let topics = [
        ("strong revenue growth operational performance excellent quarter", 2.0),
        ("revenue growth strong excellent operational margin", 1.8),
        ("weak decline loss operational risk impairment negative", -1.5),
        ("loss decline weak negative impairment writedown", -1.7),
    ];
    let mut rng = Pcg64::seed_from_u64(2);
    for i in 0..120 {
        let (text, y) = topics[i % topics.len()];
        let noise = 0.1 * rng.next_gaussian();
        writeln!(f, "{{\"text\": \"{text}\", \"response\": {}}}", y + noise).unwrap();
    }
    drop(f);
    let (corpus, vocab) =
        loader::load_text_jsonl(&path, &TokenizerConfig::default(), 0.05, 1.0).unwrap();
    assert!(vocab.len() > 5, "vocab too small: {}", vocab.len());
    assert_eq!(corpus.num_docs(), 120);

    let mut rng = Pcg64::seed_from_u64(3);
    let ds = train_test_split(&corpus, 90, &mut rng);
    let mut cfg = ExperimentConfig::quick();
    cfg.engine = EngineKind::Native;
    cfg.model.topics = 4;
    cfg.train.sweeps = 15;
    cfg.train.burnin = 3;
    let engine = EngineHandle::native();
    let (out, _) = run_with_engine(Algorithm::NonParallel, &ds, &cfg, &engine, false).unwrap();
    // Two sharply separated label groups: R^2 must be high.
    assert!(out.test_metrics.r2 > 0.5, "r2 = {}", out.test_metrics.r2);
    std::fs::remove_file(path).ok();
}

#[test]
fn malformed_inputs_fail_cleanly() {
    let path = tmp("garbage.bow");
    std::fs::write(&path, "#cfslda-bow vocab=5\n1.0 0 1 9999\n").unwrap();
    // token 9999 >= vocab 5 must be rejected by validation
    assert!(loader::load_bow(&path).is_err());

    std::fs::write(&path, "#cfslda-bow vocab=notanumber\n").unwrap();
    assert!(loader::load_bow(&path).is_err());

    std::fs::write(&path, "").unwrap();
    assert!(loader::load_bow(&path).is_err());
    std::fs::remove_file(path).ok();

    assert!(loader::load_bow(std::path::Path::new("/nonexistent/x.bow")).is_err());
}

#[test]
fn empty_documents_are_dropped_not_fatal() {
    let path = tmp("empties.jsonl");
    std::fs::write(
        &path,
        "{\"vocab_size\": 3}\n{\"tokens\": [], \"response\": 1.0}\n{\"tokens\": [0, 1], \"response\": 2.0}\n",
    )
    .unwrap();
    let c = loader::load_encoded_jsonl(&path).unwrap();
    assert_eq!(c.num_docs(), 1);
    std::fs::remove_file(path).ok();
}
