//! Seed-exact equivalence between the legacy `Vec<Document>` construction
//! path and the CSR token-arena layout (DESIGN.md §Memory layout), end to
//! end: training draws, count state, eta, zbar and final metrics must be
//! byte-identical however the corpus was built, for every `Algorithm`
//! variant at M = 4 shards — and the view-based shard handoff must copy no
//! token arrays (ledger `setup_copied_bytes` = doc ids + responses only).

use cfslda::config::schema::{EngineKind, ExperimentConfig, KernelKind};
use cfslda::data::corpus::{Corpus, Dataset, Document};
use cfslda::data::synthetic::{generate_split, SyntheticSpec};
use cfslda::parallel::leader::{run_with_engine, Algorithm};
use cfslda::runtime::EngineHandle;
use cfslda::sampler::gibbs_predict::infer_zbar_with_kernel;
use cfslda::sampler::gibbs_train::train;
use cfslda::util::rng::Pcg64;

/// Rebuild a corpus the legacy way: per-document `Document` records pushed
/// through `Corpus::new` (the pre-arena constructor every loader used).
fn legacy_rebuild(c: &Corpus) -> Corpus {
    let docs: Vec<Document> = (0..c.num_docs())
        .map(|i| Document { tokens: c.doc_tokens(i).to_vec(), response: c.response(i) })
        .collect();
    Corpus::new(docs, c.vocab_size)
}

/// Rebuild a corpus straight from arena parts (`from_parts`).
fn arena_rebuild(c: &Corpus) -> Corpus {
    Corpus::from_parts(
        c.tokens.clone(),
        c.doc_offsets.clone(),
        c.responses.clone(),
        c.vocab_size,
    )
    .unwrap()
}

fn fixture() -> (Dataset, ExperimentConfig) {
    let spec = SyntheticSpec::continuous_small();
    let mut rng = Pcg64::seed_from_u64(20170710);
    let ds = generate_split(&spec, 180, &mut rng);
    let mut cfg = ExperimentConfig::quick();
    cfg.engine = EngineKind::Native;
    cfg.train.sweeps = 12;
    cfg.train.burnin = 3;
    cfg.train.eta_every = 3;
    cfg.train.predict_sweeps = 6;
    cfg.train.predict_burnin = 2;
    cfg.parallel.shards = 4;
    cfg.parallel.threads = 4;
    (ds, cfg)
}

#[test]
fn construction_paths_produce_identical_corpora() {
    let (ds, _) = fixture();
    assert_eq!(legacy_rebuild(&ds.train), ds.train);
    assert_eq!(arena_rebuild(&ds.train), ds.train);
    assert_eq!(legacy_rebuild(&ds.test), arena_rebuild(&ds.test));
}

#[test]
fn training_is_seed_exact_across_layouts() {
    // z draws, ndt, eta, prediction zbar and final metrics must not depend
    // on how the corpus was constructed.
    let (ds, cfg) = fixture();
    let engine = EngineHandle::native();
    let run = |c: &Corpus| {
        let out = train(c, &cfg, &engine, &mut Pcg64::seed_from_u64(77)).unwrap();
        let zbar = infer_zbar_with_kernel(
            &out.model,
            &ds.test,
            &cfg.train,
            KernelKind::Auto,
            &mut Pcg64::seed_from_u64(78),
        );
        (out, zbar)
    };
    let (a, za) = run(&ds.train);
    let (b, zb) = run(&legacy_rebuild(&ds.train));
    let (c, zc) = run(&arena_rebuild(&ds.train));
    assert_eq!(a.z, b.z, "z draws diverged (legacy)");
    assert_eq!(a.z, c.z, "z draws diverged (from_parts)");
    assert_eq!(a.z_offsets, b.z_offsets);
    assert_eq!(a.counts.ndt, b.counts.ndt, "ndt diverged");
    assert_eq!(a.counts.ntw, c.counts.ntw, "ntw diverged");
    assert_eq!(a.model.eta, b.model.eta, "eta diverged");
    assert_eq!(a.model.eta, c.model.eta);
    assert_eq!(a.model.train_mse, b.model.train_mse, "final metrics diverged");
    assert_eq!(a.model.train_acc, c.model.train_acc);
    assert_eq!(a.responses, b.responses);
    assert_eq!(za, zb, "prediction zbar diverged");
    assert_eq!(za, zc);
}

#[test]
fn all_algorithms_seed_exact_across_layouts_at_m4() {
    // The full five-variant comparison (M = 4 shards): legacy-built and
    // arena-built datasets must yield byte-identical predictions, metrics
    // and per-shard summaries.
    let (ds, cfg) = fixture();
    let legacy = Dataset { train: legacy_rebuild(&ds.train), test: legacy_rebuild(&ds.test) };
    let arena = Dataset { train: arena_rebuild(&ds.train), test: arena_rebuild(&ds.test) };
    let engine = EngineHandle::native();
    for algo in Algorithm::ALL_EXTENDED {
        let (a, _) = run_with_engine(algo, &ds, &cfg, &engine, false).unwrap();
        let (b, _) = run_with_engine(algo, &legacy, &cfg, &engine, false).unwrap();
        let (c, _) = run_with_engine(algo, &arena, &cfg, &engine, false).unwrap();
        assert_eq!(a.yhat, b.yhat, "{}: yhat diverged (legacy)", algo.name());
        assert_eq!(a.yhat, c.yhat, "{}: yhat diverged (from_parts)", algo.name());
        assert_eq!(a.test_metrics, b.test_metrics, "{}: metrics diverged", algo.name());
        assert_eq!(a.test_metrics, c.test_metrics);
        for (sa, sb) in a.shards.iter().zip(&b.shards) {
            assert_eq!(sa.eta, sb.eta, "{}: shard eta diverged", algo.name());
            assert_eq!(sa.fit_mse, sb.fit_mse);
            assert_eq!(sa.docs, sb.docs);
        }
        assert_eq!(a.comm, b.comm, "{}: comm accounting diverged", algo.name());
    }
}

#[test]
fn shard_setup_copies_no_token_arrays() {
    // The acceptance bar: with view-based handoff the duplicated setup
    // bytes are per-document doc ids + responses (16 B/doc per shard
    // assignment), plus — Weighted Average only — the full training labels
    // each worker materializes for its eq. 8 weight pass (8 B/doc/worker).
    // Token arrays move exactly zero times for every parallel algorithm.
    let (ds, cfg) = fixture();
    let engine = EngineHandle::native();
    let docs = ds.train.num_docs() as u64;
    let m = cfg.parallel.shards as u64;
    for algo in [
        Algorithm::NaiveCombination,
        Algorithm::SimpleAverage,
        Algorithm::WeightedAverage,
        Algorithm::MedianAverage,
    ] {
        let (out, _) = run_with_engine(algo, &ds, &cfg, &engine, false).unwrap();
        let full_train_labels =
            if algo == Algorithm::WeightedAverage { m * docs * 8 } else { 0 };
        assert_eq!(
            out.comm.setup_copied_bytes,
            docs * 16 + full_train_labels,
            "{}: shard setup copied more than doc ids + labels",
            algo.name()
        );
        // token payload is referenced, never copied: the train corpus is
        // referenced exactly once by the shard partition (plus full-corpus
        // views for test/full-train prediction, which copy no tokens).
        assert!(out.comm.setup_referenced_bytes >= cfslda::parallel::comm::corpus_bytes(&ds.train));
        assert_eq!(out.comm.sampling_syncs, 0);
    }
}
