//! Statistical-equivalence suite for the alias-MH kernel (DESIGN.md §Perf).
//!
//! The alias kernel is exempt from the dense/sparse byte-identical
//! contract: Metropolis-Hastings draws consume a different RNG sequence.
//! Its contract is instead *statistical*: the MH correction targets the
//! exact Gibbs conditional, so per-token topic marginals, pooled topic
//! mass, held-out predictions and training fits must all agree with the
//! dense kernel within sampling noise — while every run stays fully
//! seed-deterministic. This suite pins that contract on synthetic corpora;
//! the per-token chain-level marginal tests live next to the kernel in
//! `sampler/kernel.rs`.

use cfslda::config::schema::{ExperimentConfig, KernelKind};
use cfslda::data::synthetic::{generate_split, SyntheticSpec};
use cfslda::runtime::EngineHandle;
use cfslda::sampler::gibbs_predict::{
    infer_zbar_parallel, infer_zbar_with_kernel, predict_corpus_with_kernel,
};
use cfslda::sampler::gibbs_train::train;
use cfslda::util::rng::Pcg64;
use cfslda::util::stats::{chi_square_pvalue, chi_square_stat, Summary};

/// Quick training schedule with a long prediction chain: the equivalence
/// checks compare sweep-averaged estimates, so extra predict sweeps shrink
/// chain noise on both sides of every comparison.
fn cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::quick();
    c.train.sweeps = 25;
    c.train.burnin = 5;
    c.train.eta_every = 5;
    c.train.predict_sweeps = 60;
    c.train.predict_burnin = 20;
    c
}

/// Pooled per-topic token mass of a zbar matrix: Σ_d zbar[d, t] · N_d.
/// Rows sum to one, so the topic masses of two kernels on the same corpus
/// total identically — a clean chi-square pairing.
fn pooled_topic_mass(zbar: &[f32], doc_lens: &[usize], t: usize) -> Vec<f64> {
    let mut mass = vec![0.0f64; t];
    for (d, &nd) in doc_lens.iter().enumerate() {
        for ti in 0..t {
            mass[ti] += zbar[d * t + ti] as f64 * nd as f64;
        }
    }
    mass
}

#[test]
fn alias_predict_topic_marginals_match_dense() {
    let spec = SyntheticSpec::continuous_small();
    let mut rng = Pcg64::seed_from_u64(101);
    let ds = generate_split(&spec, 180, &mut rng);
    let engine = EngineHandle::native();
    let cfg = cfg();
    let out = train(&ds.train, &cfg, &engine, &mut rng).unwrap();
    let t = out.model.t;
    let doc_lens: Vec<usize> =
        (0..ds.test.num_docs()).map(|d| ds.test.doc_len(d)).collect();

    let zd = infer_zbar_with_kernel(
        &out.model, &ds.test, &cfg.train, KernelKind::Dense,
        &mut Pcg64::seed_from_u64(7),
    );
    let za = infer_zbar_with_kernel(
        &out.model, &ds.test, &cfg.train, KernelKind::Alias,
        &mut Pcg64::seed_from_u64(7),
    );
    // every alias row is still a distribution
    for d in 0..ds.test.num_docs() {
        let s: f32 = za[d * t..(d + 1) * t].iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "doc {d} alias zbar sums to {s}");
    }

    let mass_d = pooled_topic_mass(&zd, &doc_lens, t);
    let mass_a = pooled_topic_mass(&za, &doc_lens, t);
    let total: f64 = mass_d.iter().sum();
    // per-topic proportions agree within generous sampling tolerance
    for ti in 0..t {
        let (pd, pa) = (mass_d[ti] / total, mass_a[ti] / total);
        assert!(
            (pd - pa).abs() < 0.03,
            "topic {ti}: dense proportion {pd:.4} vs alias {pa:.4}"
        );
    }
    // and the chi-square over the pooled masses sees no gross mismatch
    let (stat, dof) = chi_square_stat(&mass_a, &mass_d, 5.0);
    let p = chi_square_pvalue(stat, dof);
    assert!(p > 1e-5, "chi-square stat {stat:.2} (dof {dof}) p {p:.2e}");
}

#[test]
fn alias_heldout_predictions_within_tolerance_of_dense() {
    let spec = SyntheticSpec::continuous_small();
    let mut rng = Pcg64::seed_from_u64(202);
    let ds = generate_split(&spec, 180, &mut rng);
    let engine = EngineHandle::native();
    let cfg = cfg();
    let out = train(&ds.train, &cfg, &engine, &mut rng).unwrap();
    let ys = ds.test.responses();
    let var = Summary::from_slice(&ys).var();

    let (pd, _) = predict_corpus_with_kernel(
        &out.model, &ds.test, &cfg.train, KernelKind::Dense, &engine, Some(&ys),
        &mut Pcg64::seed_from_u64(11),
    )
    .unwrap();
    let (pa, _) = predict_corpus_with_kernel(
        &out.model, &ds.test, &cfg.train, KernelKind::Alias, &engine, Some(&ys),
        &mut Pcg64::seed_from_u64(11),
    )
    .unwrap();

    // the alias chain must clear the paper's mean-baseline bar like dense
    assert!(pa.mse < 0.6 * var, "alias mse {} vs baseline {var}", pa.mse);
    // held-out MSE within tolerance of the dense kernel's
    assert!(
        (pa.mse - pd.mse).abs() < 0.25 * pd.mse + 0.02 * var,
        "alias mse {} drifted from dense mse {} (var {var})",
        pa.mse,
        pd.mse
    );
    // per-document predictions track each other (same posterior mean, two
    // chains): small mean absolute deviation relative to the label spread
    let mad: f64 = pd
        .yhat
        .iter()
        .zip(&pa.yhat)
        .map(|(a, b)| (a - b).abs())
        .sum::<f64>()
        / pd.yhat.len() as f64;
    assert!(
        mad < 0.35 * var.sqrt(),
        "mean |yhat_dense - yhat_alias| = {mad} vs label sd {}",
        var.sqrt()
    );
}

#[test]
fn alias_training_reaches_dense_quality() {
    let spec = SyntheticSpec::continuous_small();
    let engine = EngineHandle::native();
    let mut cfg = cfg();
    let run = |kernel: KernelKind, cfg: &ExperimentConfig| {
        let mut rng = Pcg64::seed_from_u64(303);
        let ds = generate_split(&spec, 180, &mut rng);
        let mut c = cfg.clone();
        c.sampler.kernel = kernel;
        let out = train(&ds.train, &c, &engine, &mut rng).unwrap();
        out.counts.check_invariants().unwrap();
        (out, ds)
    };
    let (dense, ds) = run(KernelKind::Dense, &cfg);
    // a non-auto staleness budget must work too
    cfg.sampler.alias_staleness = 24;
    let (alias, _) = run(KernelKind::Alias, &cfg);

    assert_eq!(alias.z.len(), dense.z.len());
    assert_eq!(alias.counts.total_tokens(), dense.counts.total_tokens());
    let var = Summary::from_slice(&ds.train.responses()).var();
    // both kernels learn: in-sample fit explains most label variance, and
    // neither chain is grossly worse than the other
    assert!(dense.model.train_mse < 0.5 * var, "dense mse {}", dense.model.train_mse);
    assert!(alias.model.train_mse < 0.6 * var, "alias mse {}", alias.model.train_mse);
    let (lo, hi) = if dense.model.train_mse < alias.model.train_mse {
        (dense.model.train_mse, alias.model.train_mse)
    } else {
        (alias.model.train_mse, dense.model.train_mse)
    };
    assert!(
        hi < 2.0 * lo + 0.02 * var,
        "train mse diverged: dense {} vs alias {}",
        dense.model.train_mse,
        alias.model.train_mse
    );
}

#[test]
fn alias_kernel_is_seed_deterministic() {
    let spec = SyntheticSpec::continuous_small();
    let engine = EngineHandle::native();
    let mut cfg = cfg();
    cfg.sampler.kernel = KernelKind::Alias;
    cfg.train.predict_sweeps = 20;
    cfg.train.predict_burnin = 5;
    let run = |seed: u64| {
        let mut rng = Pcg64::seed_from_u64(seed);
        let ds = generate_split(&spec, 180, &mut rng);
        let out = train(&ds.train, &cfg, &engine, &mut rng).unwrap();
        let zbar = infer_zbar_with_kernel(
            &out.model, &ds.test, &cfg.train, KernelKind::Alias, &mut rng,
        );
        (out, zbar)
    };
    let (a, za) = run(404);
    let (b, zb) = run(404);
    assert_eq!(a.z, b.z, "alias training must repeat exactly under one seed");
    assert_eq!(a.model.eta, b.model.eta);
    assert_eq!(a.counts.ndt, b.counts.ndt);
    assert_eq!(za, zb, "alias prediction must repeat exactly under one seed");
    let (c, zc) = run(405);
    assert_ne!(a.z, c.z, "different seeds must move the chain");
    assert_ne!(za, zc);
}

#[test]
fn alias_parallel_prediction_is_jobs_independent() {
    // Per-document content-addressed seeding holds for the alias chain
    // exactly as for dense/sparse: any worker count, same bytes.
    let spec = SyntheticSpec::continuous_small();
    let mut rng = Pcg64::seed_from_u64(505);
    let ds = generate_split(&spec, 180, &mut rng);
    let engine = EngineHandle::native();
    let cfg = {
        let mut c = cfg();
        c.train.predict_sweeps = 20;
        c.train.predict_burnin = 5;
        c
    };
    let out = train(&ds.train, &cfg, &engine, &mut rng).unwrap();
    let z1 = infer_zbar_parallel(
        &out.model, &ds.test, &cfg.train, KernelKind::Alias, 99, 1,
    );
    let z5 = infer_zbar_parallel(
        &out.model, &ds.test, &cfg.train, KernelKind::Alias, 99, 5,
    );
    assert_eq!(z1, z5);
}
