//! Statistical-equivalence suite for the **supervised** (eta-active) MH
//! sweeps (DESIGN.md §Perf "Supervised MH decomposition").
//!
//! Under `resp_mode = mh` the sparse and alias kernels stop falling back to
//! the exact dense Gaussian conditional once eta activates: they propose
//! from their own unsupervised machinery and Metropolis-Hastings-correct
//! with the O(1) response ratio. That chain consumes a different RNG
//! sequence, so it is exempt from the dense/sparse byte-identical contract
//! (which `resp_mode = exact` preserves — pinned below) and carries a
//! *statistical* contract instead: the MH correction targets the exact
//! supervised conditional, so trained-model topic structure, held-out
//! predictions and training fits must agree with the exact chain within
//! sampling noise, while staying fully seed-deterministic. Per-token
//! chain-level marginal pins live next to the kernels in
//! `sampler/kernel.rs`.

use cfslda::config::schema::{ExperimentConfig, KernelKind, RespMode};
use cfslda::data::synthetic::{generate_split, SyntheticSpec};
use cfslda::parallel::leader::{run_with_engine, Algorithm};
use cfslda::runtime::EngineHandle;
use cfslda::sampler::gibbs_predict::{infer_zbar_with_kernel, predict_corpus_with_kernel};
use cfslda::sampler::gibbs_train::{train, TrainOutput};
use cfslda::util::rng::Pcg64;
use cfslda::util::stats::{chi_square_pvalue, chi_square_stat, Summary};

/// Quick training schedule with a long prediction chain: the equivalence
/// checks compare sweep-averaged estimates, so extra predict sweeps shrink
/// chain noise on both sides of every comparison. 5 burn-in + 20 supervised
/// sweeps — most of the run exercises the eta-active path under test.
fn cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::quick();
    c.train.sweeps = 25;
    c.train.burnin = 5;
    c.train.eta_every = 5;
    c.train.predict_sweeps = 60;
    c.train.predict_burnin = 20;
    c
}

/// Train on a fresh copy of the same corpus/seed with the given kernel and
/// supervised-sweep mode. Same seed => identical corpus, identical random
/// init; an exact-vs-MH pair of the same kernel shares every burn-in draw
/// and diverges only once eta activates, so topic labels stay aligned and
/// per-topic comparisons are meaningful.
fn train_with(
    kernel: KernelKind,
    resp: RespMode,
    engine: &EngineHandle,
) -> (TrainOutput, cfslda::data::corpus::Dataset) {
    let spec = SyntheticSpec::continuous_small();
    let mut rng = Pcg64::seed_from_u64(606);
    let ds = generate_split(&spec, 180, &mut rng);
    let mut c = cfg();
    c.sampler.kernel = kernel;
    c.sampler.resp_mode = resp;
    let out = train(&ds.train, &c, engine, &mut rng).unwrap();
    out.counts.check_invariants().unwrap();
    (out, ds)
}

/// Pooled per-topic token mass of a zbar matrix: Σ_d zbar[d, t] · N_d.
fn pooled_topic_mass(zbar: &[f32], doc_lens: &[usize], t: usize) -> Vec<f64> {
    let mut mass = vec![0.0f64; t];
    for (d, &nd) in doc_lens.iter().enumerate() {
        for ti in 0..t {
            mass[ti] += zbar[d * t + ti] as f64 * nd as f64;
        }
    }
    mass
}

#[test]
fn mh_supervised_topic_mass_matches_exact_chain() {
    let engine = EngineHandle::native();
    for kernel in [KernelKind::Sparse, KernelKind::Alias] {
        // Reference: the same kernel with exact supervised sweeps. Exact
        // and MH share every burn-in draw and the first eta solve, so
        // topic labels stay aligned and the only divergence is the
        // supervised sampler under test. (For sparse, the exact run is
        // additionally byte-identical to the dense reference chain.)
        let (exact, ds) = train_with(kernel, RespMode::Exact, &engine);
        let (mh, _) = train_with(kernel, RespMode::Mh, &engine);
        let t = exact.model.t;
        assert_eq!((exact.resp_proposed, exact.resp_accepted), (0, 0));
        assert!(mh.resp_proposed > 0, "{kernel:?} supervised phase never ran MH");
        // the MH chain must actually move: a healthy share of proposals
        // accepted when each proposal carries one factor of the target
        assert!(
            mh.resp_accepted * 3 > mh.resp_proposed,
            "{kernel:?} acceptance collapsed: {}/{}",
            mh.resp_accepted,
            mh.resp_proposed
        );
        // in-training pooled topic mass (final counts) stays aligned
        let nt_ref: Vec<f64> = exact.counts.nt.iter().map(|&x| x as f64).collect();
        let nt_mh: Vec<f64> = mh.counts.nt.iter().map(|&x| x as f64).collect();
        let train_total: f64 = nt_ref.iter().sum();
        for ti in 0..t {
            let (pd, pm) = (nt_ref[ti] / train_total, nt_mh[ti] / train_total);
            assert!(
                (pd - pm).abs() < 0.04,
                "{kernel:?} training topic {ti}: exact proportion {pd:.4} vs MH {pm:.4}"
            );
        }
        // held-out pooled topic mass through the *same* dense predictor and
        // seed: only the trained models differ
        let doc_lens: Vec<usize> = (0..ds.test.num_docs()).map(|d| ds.test.doc_len(d)).collect();
        let c = cfg();
        let zbar_of = |out: &TrainOutput| {
            infer_zbar_with_kernel(
                &out.model, &ds.test, &c.train, KernelKind::Dense,
                &mut Pcg64::seed_from_u64(7),
            )
        };
        let mass_ref = pooled_topic_mass(&zbar_of(&exact), &doc_lens, t);
        let mass_mh = pooled_topic_mass(&zbar_of(&mh), &doc_lens, t);
        let total: f64 = mass_ref.iter().sum();
        for ti in 0..t {
            let (pd, pm) = (mass_ref[ti] / total, mass_mh[ti] / total);
            assert!(
                (pd - pm).abs() < 0.04,
                "{kernel:?} topic {ti}: exact proportion {pd:.4} vs MH {pm:.4}"
            );
        }
        let (stat, dof) = chi_square_stat(&mass_mh, &mass_ref, 5.0);
        let p = chi_square_pvalue(stat, dof);
        assert!(p > 1e-5, "{kernel:?} chi-square stat {stat:.2} (dof {dof}) p {p:.2e}");
    }
}

#[test]
fn mh_heldout_predictions_within_tolerance_of_dense() {
    let engine = EngineHandle::native();
    let (dense, ds) = train_with(KernelKind::Dense, RespMode::Exact, &engine);
    let ys = ds.test.responses();
    let var = Summary::from_slice(&ys).var();
    let c = cfg();
    let predict = |out: &TrainOutput| {
        predict_corpus_with_kernel(
            &out.model, &ds.test, &c.train, KernelKind::Dense, &engine, Some(&ys),
            &mut Pcg64::seed_from_u64(11),
        )
        .unwrap()
        .0
    };
    let pd = predict(&dense);

    for kernel in [KernelKind::Sparse, KernelKind::Alias] {
        let (mh, _) = train_with(kernel, RespMode::Mh, &engine);
        let pm = predict(&mh);
        // the MH-trained model must clear the paper's mean-baseline bar
        assert!(pm.mse < 0.6 * var, "{kernel:?} mse {} vs baseline {var}", pm.mse);
        // held-out MSE within tolerance of the exact-trained model's (two
        // independently trained chains of the same posterior, so the band
        // is wider than the same-model prediction comparison in
        // alias_equivalence.rs)
        assert!(
            (pm.mse - pd.mse).abs() < 0.35 * pd.mse + 0.05 * var,
            "{kernel:?} mse {} drifted from dense mse {} (var {var})",
            pm.mse,
            pd.mse
        );
        // per-document predictions track each other (same posterior mean,
        // two independently trained models)
        let mad: f64 = pd
            .yhat
            .iter()
            .zip(&pm.yhat)
            .map(|(a, b)| (a - b).abs())
            .sum::<f64>()
            / pd.yhat.len() as f64;
        assert!(
            mad < 0.5 * var.sqrt(),
            "{kernel:?} mean |yhat_exact - yhat_mh| = {mad} vs label sd {}",
            var.sqrt()
        );
        // and the training fit itself stays in the same quality band
        let (lo, hi) = if dense.model.train_mse < mh.model.train_mse {
            (dense.model.train_mse, mh.model.train_mse)
        } else {
            (mh.model.train_mse, dense.model.train_mse)
        };
        assert!(
            hi < 2.0 * lo + 0.02 * var,
            "{kernel:?} train mse diverged: dense {} vs MH {}",
            dense.model.train_mse,
            mh.model.train_mse
        );
    }
}

#[test]
fn mh_supervised_training_is_seed_deterministic() {
    let engine = EngineHandle::native();
    for kernel in [KernelKind::Sparse, KernelKind::Alias] {
        let run = |seed: u64| {
            let spec = SyntheticSpec::continuous_small();
            let mut rng = Pcg64::seed_from_u64(seed);
            let ds = generate_split(&spec, 180, &mut rng);
            let mut c = cfg();
            c.sampler.kernel = kernel;
            c.sampler.resp_mode = RespMode::Mh;
            train(&ds.train, &c, &engine, &mut rng).unwrap()
        };
        let (a, b) = (run(404), run(404));
        assert_eq!(a.z, b.z, "{kernel:?} supervised MH must repeat under one seed");
        assert_eq!(a.model.eta, b.model.eta);
        assert_eq!(a.counts.ndt, b.counts.ndt);
        assert_eq!(
            (a.resp_proposed, a.resp_accepted),
            (b.resp_proposed, b.resp_accepted),
            "{kernel:?} MH counters must be deterministic too"
        );
        let c = run(405);
        assert_ne!(a.z, c.z, "{kernel:?}: different seeds must move the chain");
    }
}

#[test]
fn mh_supervised_parallel_run_is_thread_count_independent() {
    // Worker RNG streams are derived per shard before the fan-out, so a
    // supervised-MH parallel run must produce identical bytes for any
    // thread count (the jobs-independence contract of the exact path).
    let spec = SyntheticSpec::continuous_small();
    let mut rng = Pcg64::seed_from_u64(505);
    let ds = generate_split(&spec, 180, &mut rng);
    let engine = EngineHandle::native();
    let mut c = cfg();
    c.engine = cfslda::config::schema::EngineKind::Native;
    c.sampler.kernel = KernelKind::Sparse;
    c.sampler.resp_mode = RespMode::Mh;
    c.train.predict_sweeps = 20;
    c.train.predict_burnin = 5;
    c.parallel.shards = 4;
    let mut run = |threads: usize| {
        c.parallel.threads = threads;
        run_with_engine(Algorithm::SimpleAverage, &ds, &c, &engine, false)
            .unwrap()
            .0
    };
    let a = run(1);
    let b = run(4);
    assert_eq!(a.yhat, b.yhat);
    assert_eq!(a.test_metrics, b.test_metrics);
}

/// `resp_mode = exact` pins the historical byte-exact contract: the sparse
/// kernel's supervised sweeps delegate to the same `sweep_doc_gauss` draws
/// as the dense kernel (and `auto` on dense resolves to exact), so z,
/// counts and eta agree bit-for-bit.
#[test]
fn exact_resp_mode_preserves_dense_sparse_byte_contract() {
    let engine = EngineHandle::native();
    let (dense_auto, _) = train_with(KernelKind::Dense, RespMode::Auto, &engine);
    let (dense_exact, _) = train_with(KernelKind::Dense, RespMode::Exact, &engine);
    let (sparse_exact, _) = train_with(KernelKind::Sparse, RespMode::Exact, &engine);
    assert_eq!(dense_auto.z, dense_exact.z, "auto must resolve to exact on dense");
    assert_eq!(dense_auto.model.eta, dense_exact.model.eta);
    assert_eq!(dense_exact.z, sparse_exact.z, "sparse exact diverged from dense");
    assert_eq!(dense_exact.counts.ndt, sparse_exact.counts.ndt);
    assert_eq!(dense_exact.model.eta, sparse_exact.model.eta);
    assert_eq!((dense_exact.resp_proposed, sparse_exact.resp_proposed), (0, 0));
    // while the MH chain on the same seed is a genuinely different (but
    // valid) sequence
    let (sparse_mh, _) = train_with(KernelKind::Sparse, RespMode::Mh, &engine);
    assert_ne!(sparse_mh.z, sparse_exact.z);
    assert!(sparse_mh.resp_proposed > 0);
}
