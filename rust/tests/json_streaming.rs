//! Differential suite: the streaming serve codec vs the tree reference.
//!
//! The tree parsers (`parse_predict` & co) are the semantics oracle; the
//! streaming parsers must agree on accept/reject and on every parsed value
//! for the shared corpus below. The one *documented* divergence — integer
//! seeds in (2^53, u64::MAX] stream exactly but are rejected by the tree
//! (which would otherwise round them through f64) — is pinned separately.
//!
//! Also here: torture tests that request limits are enforced *during*
//! streaming (before the body is fully buffered into the arena), and the
//! feature-gated allocation pin for the warmed hot path (run under
//! `--features bench-alloc --test-threads=1`).

use cfslda::config::json::JsonWriter;
use cfslda::serve::batcher::ArenaBuilder;
use cfslda::serve::protocol::{
    self, MAX_DOCS_PER_REQUEST, MAX_TOKENS_PER_DOC,
};

/// Streaming-parse a /predict body, returning (docs, seed) on success.
fn stream_predict(body: &str) -> anyhow::Result<(Vec<Vec<u32>>, Option<u64>)> {
    let mut b = ArenaBuilder::new();
    let seed = protocol::parse_predict_streamed(body.as_bytes(), &mut b)?;
    let arena = b.finish();
    let docs = (0..arena.num_docs()).map(|d| arena.doc(d).to_vec()).collect();
    Ok((docs, seed))
}

fn stream_text(body: &str) -> anyhow::Result<(Vec<String>, Option<u64>)> {
    let mut texts = Vec::new();
    let seed = protocol::parse_text_streamed(body.as_bytes(), &mut texts)?;
    Ok((texts, seed))
}

/// Bodies on which tree and streaming must agree exactly (accept/reject
/// *and* every parsed value). Seeds stay below 2^53 here; the documented
/// divergence above that is covered by its own test.
const PREDICT_CORPUS: &[&str] = &[
    // valid
    r#"{"docs": [[0, 1, 2]]}"#,
    r#"{"docs": [[0], [1, 1], [2, 2, 2]], "seed": 7}"#,
    r#"{"docs": [[4294967295]], "seed": 0}"#,
    r#"{"seed": 9007199254740992, "docs": [[1]]}"#, // 2^53: exact in both
    r#"{"docs": [[1e2, 4.0]]}"#,                    // integral floats as ids
    r#"{"docs": [[0,1],[2,3]], "docs": [[5]]}"#,    // duplicate key: last wins
    r#"{"extra": {"deep": [1, {"x": null}]}, "docs": [[9]]}"#, // unknown keys skipped
    "{\n  \"docs\" : [ [ 0 , 1 ] ]\n}",             // whitespace-tolerant
    r#"{"docs": [[1]], "seed": 1.5e1}"#,            // 15.0 is an integral seed
    // invalid in both
    r#"{}"#,
    r#"{"docs": []}"#,
    r#"{"docs": [[]]}"#,
    r#"{"docs": [[-1]]}"#,
    r#"{"docs": [[0.5]]}"#,
    r#"{"docs": [[4294967296]]}"#, // > u32::MAX
    r#"{"docs": [0]}"#,
    r#"{"docs": {"0": [1]}}"#,
    r#"{"docs": [[1]], "seed": -3}"#,
    r#"{"docs": [[1]], "seed": 1.5}"#,
    r#"{"docs": [[1]], "seed": "7"}"#,
    r#"[{"docs": [[1]]}]"#,
    r#""docs""#,
    r#"{"docs": [[1]]"#,       // truncated object
    r#"{"docs": [[1, ]]}"#,    // trailing comma
    r#"{"docs": [[1]]} x"#,    // trailing garbage
    r#"{"docs" [[1]]}"#,       // missing colon
    r#"{"docs": [[1]], }"#,    // trailing comma in object
    r#"{"docs": [["a"]]}"#,
    r#"{"docs": [[01]]}"#, // leading zero: the shared lexer is lenient, both read 1
    r#"{"docs": [[+1]]}"#,
    r#"{"docs": [[1e]]}"#,     // bad exponent
    "{\"docs\": [[1]],\x00}",  // control byte outside string
    "not json at all",
    "",
];

#[test]
fn predict_differential_corpus() {
    for body in PREDICT_CORPUS {
        let tree = protocol::parse_predict(body);
        let streamed = stream_predict(body);
        match (&tree, &streamed) {
            (Ok(t), Ok(s)) => {
                assert_eq!(t.docs, s.0, "docs differ on {body:?}");
                assert_eq!(t.seed, s.1, "seed differs on {body:?}");
            }
            (Err(_), Err(_)) => {}
            _ => panic!(
                "accept/reject divergence on {body:?}: tree={:?} streamed={:?}",
                tree.as_ref().map(|t| t.docs.len()),
                streamed.as_ref().map(|s| s.0.len()),
            ),
        }
    }
}

const TEXT_CORPUS: &[&str] = &[
    r#"{"texts": ["hello world"]}"#,
    r#"{"texts": ["a", "b c", ""], "seed": 3}"#,
    r#"{"texts": ["tab\there \"quoted\" back\\slash / \b\f\n\r\t"]}"#,
    r#"{"texts": ["Aé中"]}"#,
    r#"{"texts": ["pair: 😀 done"]}"#, // surrogate pair -> 😀
    r#"{"texts": ["\ud800"]}"#,                  // lone high surrogate: reject
    r#"{"texts": ["\ud83dA"]}"#,            // high + non-low: reject
    r#"{"texts": ["bad \x escape"]}"#,
    r#"{"texts": ["unterminated}"#,
    r#"{"texts": [42]}"#,
    r#"{"texts": []}"#,
    r#"{}"#,
];

#[test]
fn text_differential_corpus() {
    for body in TEXT_CORPUS {
        let tree = protocol::parse_text(body);
        let streamed = stream_text(body);
        match (&tree, &streamed) {
            (Ok(t), Ok(s)) => {
                assert_eq!(t.texts, s.0, "texts differ on {body:?}");
                assert_eq!(t.seed, s.1, "seed differs on {body:?}");
            }
            (Err(_), Err(_)) => {}
            _ => panic!("accept/reject divergence on {body:?}"),
        }
    }
}

#[test]
fn reload_differential_corpus() {
    for body in &[
        r#"{"path": "/tmp/m.bin"}"#,
        r#"{"path": "with \"quotes\""}"#,
        r#"{}"#,
        r#"{"other": [1, 2, {"k": true}]}"#,
        "",
        "   \t\n",
        r#"{"path": 7}"#,
        r#"{"path"#,
        r#"[1"#,
        r#"null"#,
        r#"42"#,
    ] {
        let tree = protocol::parse_reload(body);
        let streamed = protocol::parse_reload_streamed(body.as_bytes());
        match (&tree, &streamed) {
            (Ok(t), Ok(s)) => assert_eq!(t, s, "path differs on {body:?}"),
            (Err(_), Err(_)) => {}
            _ => panic!(
                "accept/reject divergence on {body:?}: tree={tree:?} streamed={streamed:?}"
            ),
        }
    }
}

// ---- seed precision (satellite 1) ---------------------------------------

#[test]
fn seed_u64_max_streams_exactly_and_tree_rejects() {
    let body = r#"{"docs": [[1]], "seed": 18446744073709551615}"#;
    let (_, seed) = stream_predict(body).unwrap();
    assert_eq!(seed, Some(u64::MAX));
    // The tree path would round through f64; it must refuse, not round.
    let err = protocol::parse_predict(body).unwrap_err();
    assert!(format!("{err:#}").contains("exactly representable"), "got: {err:#}");
}

#[test]
fn seed_2p53_plus_1_streams_exactly_and_tree_rejects() {
    let body = r#"{"docs": [[1]], "seed": 9007199254740993}"#;
    let (_, seed) = stream_predict(body).unwrap();
    assert_eq!(seed, Some((1u64 << 53) + 1));
    assert!(protocol::parse_predict(body).is_err());
}

// ---- nesting bombs -------------------------------------------------------

#[test]
fn nesting_bombs_rejected_by_both() {
    let deep_arr = format!("{}{}", "[".repeat(50_000), "]".repeat(50_000));
    let body = format!(r#"{{"docs": {deep_arr}}}"#);
    assert!(protocol::parse_predict(&body).is_err());
    assert!(stream_predict(&body).is_err());

    let mut deep_obj = String::from(r#"{"docs": [[1]], "x": "#);
    for _ in 0..50_000 {
        deep_obj.push_str(r#"{"y": "#);
    }
    // Never closed; depth blows the cap long before EOF either way.
    assert!(protocol::parse_predict(&deep_obj).is_err());
    assert!(stream_predict(&deep_obj).is_err());
}

// ---- limits enforced mid-scan (satellite 3) ------------------------------

#[test]
fn doc_row_limit_enforced_during_streaming() {
    // One row past the cap, then *deliberately truncated* JSON: only a
    // parser that rejects mid-scan can produce the limit error here.
    let mut body = String::from(r#"{"docs": ["#);
    for _ in 0..(MAX_DOCS_PER_REQUEST + 1) {
        body.push_str("[1],");
    }
    let err = stream_predict(&body).unwrap_err();
    assert!(
        format!("{err:#}").contains("rows"),
        "expected the row-limit error before the truncation error, got: {err:#}"
    );
}

#[test]
fn token_limit_enforced_during_streaming() {
    let mut body = String::from(r#"{"docs": [["#);
    for _ in 0..(MAX_TOKENS_PER_DOC + 1) {
        body.push_str("7,");
    }
    let err = stream_predict(&body).unwrap_err();
    assert!(
        format!("{err:#}").contains("tokens"),
        "expected the token-limit error before the truncation error, got: {err:#}"
    );
}

#[test]
fn limits_match_tree_on_complete_bodies() {
    // A complete over-limit body: both codecs reject.
    let mut body = String::from(r#"{"docs": ["#);
    for i in 0..(MAX_DOCS_PER_REQUEST + 1) {
        if i > 0 {
            body.push(',');
        }
        body.push_str("[1]");
    }
    body.push_str("]}");
    assert!(protocol::parse_predict(&body).is_err());
    assert!(stream_predict(&body).is_err());
}

// ---- response rendering --------------------------------------------------

#[test]
fn streamed_responses_are_byte_identical_to_tree() {
    let yhat = [0.0, -1.5, 3.25, 1e-9, 12345.0];
    let mut w = JsonWriter::new();
    protocol::predict_response_into(&mut w, &yhat, 42, 3);
    assert_eq!(w.as_str(), protocol::predict_response(&yhat, 42, 3));

    protocol::error_response_into(&mut w, "bad \"input\"\n");
    assert_eq!(w.as_str(), protocol::error_response("bad \"input\"\n"));
}

// ---- allocation pin (satellite 4 / acceptance) ---------------------------

/// The warmed parse+serialize path must not touch the heap: target 0,
/// hard cap 2 allocations per request. Only meaningful with the counting
/// allocator installed and no concurrent tests (`--features bench-alloc
/// -- --test-threads=1`, which is how CI runs it).
#[cfg(feature = "bench-alloc")]
#[test]
fn warmed_predict_codec_is_allocation_free() {
    use cfslda::util::alloc_count;

    let body = br#"{"docs": [[0, 1, 2, 3], [4, 5], [6, 7, 8]], "seed": 11}"#;
    let mut builder = ArenaBuilder::new();
    let mut w = JsonWriter::with_capacity(256);
    let mut yhat: Vec<f64> = Vec::with_capacity(8);
    let mut run_once = |builder: &mut ArenaBuilder, w: &mut JsonWriter, yhat: &mut Vec<f64>| {
        let seed = protocol::parse_predict_streamed(body, builder).unwrap().unwrap();
        let arena = builder.finish();
        yhat.clear();
        for d in 0..arena.num_docs() {
            yhat.push(arena.doc(d).len() as f64);
        }
        protocol::predict_response_into(w, yhat, seed, 1);
        builder.reclaim(arena);
    };
    for _ in 0..8 {
        run_once(&mut builder, &mut w, &mut yhat);
    }
    const ITERS: u64 = 32;
    let before = alloc_count::snapshot();
    for _ in 0..ITERS {
        run_once(&mut builder, &mut w, &mut yhat);
    }
    let (allocs, bytes) = alloc_count::delta(before);
    let per_req = allocs as f64 / ITERS as f64;
    assert!(
        per_req <= 2.0,
        "warmed codec path allocates: {per_req} allocs/request \
         ({bytes} bytes over {ITERS} requests)"
    );
    assert_eq!(allocs, 0, "target is zero allocations, measured {allocs} over {ITERS} requests");
}
