"""L1 loglik kernel vs oracle + density sanity checks."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from python.compile.kernels import ref
from python.compile.kernels.loglik import loglik

BLOCK = 32


@given(
    blocks=st.integers(1, 6),
    t=st.sampled_from([1, 4, 8, 32]),
    rho=st.floats(0.05, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_loglik_matches_ref(blocks, t, rho, seed):
    rng = np.random.default_rng(seed)
    y = jnp.asarray(rng.normal(size=blocks * BLOCK).astype(np.float32))
    mu = jnp.asarray(rng.normal(size=(blocks * BLOCK, t)).astype(np.float32))
    got = loglik(y, mu, jnp.float32(rho), block=BLOCK)
    np.testing.assert_allclose(got, ref.loglik_ref(y, mu, rho), rtol=1e-3, atol=1e-3)


def test_loglik_peak_at_mean(rng):
    """Density must be maximal where mu == y."""
    y = jnp.zeros(32, jnp.float32)
    mu = jnp.asarray(np.linspace(-3, 3, 32 * 4).reshape(32, 4).astype(np.float32))
    ll = np.asarray(loglik(y, mu, jnp.float32(1.0), block=32))
    best = np.abs(np.asarray(mu)).argmin(axis=1)
    np.testing.assert_array_equal(ll.argmax(axis=1), best)


def test_loglik_matches_scipy_formula(rng):
    y = rng.normal(size=32).astype(np.float32)
    mu = rng.normal(size=(32, 3)).astype(np.float32)
    rho = 0.7
    got = np.asarray(loglik(jnp.asarray(y), jnp.asarray(mu), jnp.float32(rho), block=32))
    want = -0.5 * np.log(2 * np.pi * rho) - (y[:, None] - mu) ** 2 / (2 * rho)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
