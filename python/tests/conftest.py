"""Shared pytest fixtures + hypothesis profile for the kernel test suite."""

import numpy as np
import pytest
from hypothesis import HealthCheck, settings

# interpret-mode Pallas is slow; keep sweeps small but meaningful.
settings.register_profile(
    "cfslda",
    max_examples=20,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("cfslda")


@pytest.fixture
def rng():
    return np.random.default_rng(20170710)
