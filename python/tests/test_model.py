"""L2 model graphs vs numpy closed forms (the algebra rust will execute)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from python.compile import model

BLOCK = model and 32  # kernels inside model use their default 128-blocks;
# here we always build inputs at multiples of 128 to satisfy them.


def _pad128(n):
    return ((n + 127) // 128) * 128


def _ridge_closed_form(z, y, w, lam, mu):
    t = z.shape[1]
    a = z.T @ (w[:, None] * z) + lam * np.eye(t)
    b = z.T @ (w * y) + lam * mu
    return np.linalg.solve(a, b)


@given(
    d=st.integers(10, 200),
    t=st.sampled_from([2, 4, 8, 16]),
    lam=st.floats(0.1, 10.0),
    mu=st.floats(-1.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_eta_solve_matches_closed_form(d, t, lam, mu, seed):
    rng = np.random.default_rng(seed)
    dp = _pad128(d)
    z = np.zeros((dp, t), np.float32)
    w = np.zeros(dp, np.float32)
    y = np.zeros(dp, np.float32)
    # simplex-ish rows like real zbar
    raw = rng.dirichlet(np.ones(t), size=d).astype(np.float32)
    z[:d] = raw
    w[:d] = 1.0
    y[:d] = rng.normal(size=d).astype(np.float32)
    eta, mse, wsum = model.eta_solve(
        jnp.asarray(z), jnp.asarray(y), jnp.asarray(w),
        jnp.float32(lam), jnp.float32(mu),
    )
    want = _ridge_closed_form(z[:d].astype(np.float64), y[:d].astype(np.float64),
                              np.ones(d), lam, mu)
    np.testing.assert_allclose(np.asarray(eta), want, rtol=2e-2, atol=2e-2)
    yhat = z[:d] @ np.asarray(eta)
    np.testing.assert_allclose(float(mse), np.mean((y[:d] - yhat) ** 2), rtol=1e-3, atol=1e-4)
    assert float(wsum) == d


def test_eta_solve_recovers_true_eta():
    """Noise-free responses: eta_solve must recover the generating eta."""
    rng = np.random.default_rng(7)
    d, t = 512, 8
    z = rng.dirichlet(np.ones(t), size=d).astype(np.float32)
    eta_true = rng.normal(size=t).astype(np.float32)
    y = (z @ eta_true).astype(np.float32)
    w = np.ones(d, np.float32)
    eta, mse, _ = model.eta_solve(
        jnp.asarray(z), jnp.asarray(y), jnp.asarray(w),
        jnp.float32(1e-4), jnp.float32(0.0),
    )
    np.testing.assert_allclose(np.asarray(eta), eta_true, rtol=5e-2, atol=5e-2)
    assert float(mse) < 1e-4


@given(seed=st.integers(0, 2**31 - 1))
def test_predict_fn_metrics(seed):
    rng = np.random.default_rng(seed)
    b, t = 128, 4
    z = rng.dirichlet(np.ones(t), size=b).astype(np.float32)
    eta = rng.normal(size=t).astype(np.float32)
    y = rng.normal(size=b).astype(np.float32)
    w = (rng.random(b) > 0.4).astype(np.float32)
    yhat, mse, acc = model.predict_fn(
        jnp.asarray(z), jnp.asarray(eta), jnp.asarray(y), jnp.asarray(w))
    want_yhat = z @ eta
    np.testing.assert_allclose(np.asarray(yhat), want_yhat, rtol=1e-4, atol=1e-4)
    m = w > 0
    np.testing.assert_allclose(
        float(mse), np.mean((y[m] - want_yhat[m]) ** 2), rtol=1e-3, atol=1e-4)
    want_acc = np.mean((want_yhat[m] > 0.5) == (y[m] > 0.5))
    np.testing.assert_allclose(float(acc), want_acc, rtol=1e-4, atol=1e-4)


def test_combine_fn_normalizes():
    rng = np.random.default_rng(3)
    p = rng.normal(size=(4, 128)).astype(np.float32)
    w = np.array([2.0, 2.0, 2.0, 2.0], np.float32)  # unnormalized uniform
    yhat, wn = model.combine_fn(jnp.asarray(p), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(wn), np.full(4, 0.25), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(yhat), p.mean(axis=0), rtol=1e-4, atol=1e-5)


def test_cg_solve_vs_numpy():
    rng = np.random.default_rng(11)
    for t in (2, 8, 32, 64):
        m = rng.normal(size=(t, t)).astype(np.float32)
        a = m @ m.T + np.eye(t, dtype=np.float32) * t  # SPD, well-conditioned
        b = rng.normal(size=t).astype(np.float32)
        x = model.cg_solve(jnp.asarray(a), jnp.asarray(b), iters=2 * t)
        want = np.linalg.solve(a.astype(np.float64), b.astype(np.float64))
        np.testing.assert_allclose(np.asarray(x), want, rtol=1e-3, atol=1e-3)
