"""L1 predict kernel vs oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from python.compile.kernels import ref
from python.compile.kernels.predict import predict

BLOCK = 32


@given(
    blocks=st.integers(1, 8),
    t=st.sampled_from([1, 3, 8, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_predict_matches_ref(blocks, t, seed):
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(blocks * BLOCK, t)).astype(np.float32))
    eta = jnp.asarray(rng.normal(size=t).astype(np.float32))
    got = predict(z, eta, block=BLOCK)
    np.testing.assert_allclose(got, ref.predict_ref(z, eta), rtol=1e-4, atol=1e-4)


def test_predict_zero_eta(rng):
    z = jnp.asarray(rng.normal(size=(64, 5)).astype(np.float32))
    out = predict(z, jnp.zeros(5, jnp.float32), block=32)
    assert float(jnp.abs(out).max()) == 0.0


def test_predict_one_hot_rows(rng):
    """A doc fully in topic t must predict exactly eta_t."""
    t = 8
    eta = jnp.asarray(rng.normal(size=t).astype(np.float32))
    z = jnp.asarray(np.eye(t, dtype=np.float32).repeat(4, axis=0))  # 32 rows
    out = predict(z, eta, block=32)
    np.testing.assert_allclose(out, np.asarray(eta).repeat(4), rtol=1e-5, atol=1e-6)
