"""L1 combine kernel vs oracle + combiner invariants (paper eqs. 7-9)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from python.compile.kernels import ref
from python.compile.kernels.combine import combine

BLOCK = 32


@given(
    m=st.integers(1, 16),
    blocks=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_combine_matches_ref(m, blocks, seed):
    rng = np.random.default_rng(seed)
    p = jnp.asarray(rng.normal(size=(m, blocks * BLOCK)).astype(np.float32))
    w = rng.random(m).astype(np.float32) + 0.01
    wn = jnp.asarray(w / w.sum())
    got = combine(p, wn, block=BLOCK)
    np.testing.assert_allclose(got, ref.combine_ref(p, jnp.asarray(w)), rtol=1e-4, atol=1e-4)


@given(m=st.integers(2, 8), seed=st.integers(0, 2**31 - 1))
def test_simple_average_is_uniform_weights(m, seed):
    """Simple Average (eq. 7) == Weighted Average with equal weights."""
    rng = np.random.default_rng(seed)
    p = rng.normal(size=(m, 64)).astype(np.float32)
    uniform = np.full(m, 1.0 / m, np.float32)
    got = combine(jnp.asarray(p), jnp.asarray(uniform), block=32)
    np.testing.assert_allclose(got, p.mean(axis=0), rtol=1e-4, atol=1e-4)


def test_combine_one_hot_weight_selects_shard(rng):
    p = rng.normal(size=(5, 64)).astype(np.float32)
    w = np.zeros(5, np.float32)
    w[3] = 1.0
    got = combine(jnp.asarray(p), jnp.asarray(w), block=32)
    np.testing.assert_allclose(got, p[3], rtol=1e-5, atol=1e-6)


def test_combine_padding_shards_inert(rng):
    """Zero-weight padding shards (rust pads M up to the bucket) are no-ops."""
    p = rng.normal(size=(4, 64)).astype(np.float32)
    w = rng.random(4).astype(np.float32)
    w /= w.sum()
    pp = np.concatenate([p, rng.normal(size=(12, 64)).astype(np.float32)])
    wp = np.concatenate([w, np.zeros(12, np.float32)])
    a = combine(jnp.asarray(p), jnp.asarray(w), block=32)
    b = combine(jnp.asarray(pp), jnp.asarray(wp), block=32)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
