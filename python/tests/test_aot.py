"""AOT emission smoke: HLO text is produced, parseable-looking, manifest sane.

The real cross-language check (rust loads + executes the artifacts and
matches the native implementation) lives in rust/tests/integration_runtime.rs;
here we validate the python half in isolation using tiny topic buckets so the
test stays fast.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from python.compile import aot, model


def test_to_hlo_text_smoke(tmp_path):
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text


def test_lower_entry_writes_file_and_metadata(tmp_path):
    specs = (jax.ShapeDtypeStruct((8, 2), jnp.float32),)
    entry = aot.lower_entry("tiny", lambda z: (jnp.sum(z),), specs, str(tmp_path))
    path = tmp_path / "tiny.hlo.txt"
    assert path.exists()
    assert entry["hlo_bytes"] == path.stat().st_size
    assert entry["params"] == [{"shape": [8, 2], "dtype": "float32"}]
    assert len(entry["sha256_16"]) == 16


def test_main_emits_manifest(tmp_path, monkeypatch):
    # Shrink buckets so lowering is fast: T=4 only, small rows.
    monkeypatch.setattr(model, "ROW_BUCKET", 256)
    monkeypatch.setattr(model, "SHARD_BUCKET", 4)
    rc = aot.main(["--out", str(tmp_path), "--topics", "4"])
    assert rc == 0
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    names = {f["name"] for f in manifest["functions"]}
    assert names == {"eta_solve_T4", "gram_T4", "predict_T4", "loglik_T4", "combine_M4"}
    for fn in manifest["functions"]:
        p = tmp_path / fn["file"]
        assert p.exists() and p.stat().st_size == fn["hlo_bytes"]
        head = p.read_text()[:200]
        assert "HloModule" in head
    assert manifest["row_bucket"] == 256
    assert manifest["dtype"] == "f32"


def test_no_lapack_custom_calls(tmp_path, monkeypatch):
    """The lowered HLO must not contain jaxlib LAPACK custom-calls — the rust
    PJRT client (xla_extension 0.5.1) cannot resolve them. This is why the
    ridge solve uses CG (model.cg_solve) instead of jnp.linalg.solve."""
    monkeypatch.setattr(model, "ROW_BUCKET", 256)
    specs = model.make_specs(4)
    name, (fn, sp) = next(iter(specs.items()))
    entry = aot.lower_entry(name, fn, sp, str(tmp_path))
    text = (tmp_path / entry["file"]).read_text()
    assert "lapack" not in text.lower()
    assert "custom-call" not in text.lower()
