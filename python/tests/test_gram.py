"""L1 gram kernel vs the pure-jnp oracle (hypothesis shape/seed sweep)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from python.compile.kernels import ref
from python.compile.kernels.gram import gram

BLOCK = 32


def _mk(rng, d, t, mask_frac):
    z = rng.normal(size=(d, t)).astype(np.float32)
    w = (rng.random(d) > mask_frac).astype(np.float32)
    y = rng.normal(size=d).astype(np.float32)
    return jnp.asarray(z), jnp.asarray(w), jnp.asarray(y)


@given(
    blocks=st.integers(1, 6),
    t=st.sampled_from([2, 5, 8, 16]),
    mask_frac=st.floats(0.0, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_matches_ref(blocks, t, mask_frac, seed):
    rng = np.random.default_rng(seed)
    z, w, y = _mk(rng, blocks * BLOCK, t, mask_frac)
    g, b = gram(z, w, y, block=BLOCK)
    g_ref, b_ref = ref.gram_ref(z, w, y)
    np.testing.assert_allclose(g, g_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(b, b_ref, rtol=1e-4, atol=1e-4)


def test_gram_all_masked_is_zero(rng):
    z = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
    w = jnp.zeros(64, jnp.float32)
    y = jnp.asarray(rng.normal(size=64).astype(np.float32))
    g, b = gram(z, w, y, block=32)
    assert float(jnp.abs(g).max()) == 0.0
    assert float(jnp.abs(b).max()) == 0.0


def test_gram_is_symmetric_psd(rng):
    z = jnp.asarray(rng.normal(size=(128, 12)).astype(np.float32))
    w = jnp.ones(128, jnp.float32)
    y = jnp.zeros(128, jnp.float32)
    g, _ = gram(z, w, y, block=32)
    np.testing.assert_allclose(g, g.T, atol=1e-4)
    eig = np.linalg.eigvalsh(np.asarray(g, dtype=np.float64))
    assert eig.min() > -1e-3


def test_gram_padding_rows_inert(rng):
    """Appending w=0 rows must not change the result."""
    z = rng.normal(size=(64, 6)).astype(np.float32)
    w = np.ones(64, np.float32)
    y = rng.normal(size=64).astype(np.float32)
    zp = np.concatenate([z, rng.normal(size=(32, 6)).astype(np.float32)])
    wp = np.concatenate([w, np.zeros(32, np.float32)])
    yp = np.concatenate([y, rng.normal(size=32).astype(np.float32)])
    g1, b1 = gram(jnp.asarray(z), jnp.asarray(w), jnp.asarray(y), block=32)
    g2, b2 = gram(jnp.asarray(zp), jnp.asarray(wp), jnp.asarray(yp), block=32)
    np.testing.assert_allclose(g1, g2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(b1, b2, rtol=1e-5, atol=1e-5)
