"""L2: the sLDA estimation/prediction algebra as JAX graphs.

These are the dense, batched pieces of the paper's algorithm — everything
that is NOT the token-sequential collapsed Gibbs sweep (which lives in the
rust coordinator, rust/src/sampler/). Each function here calls the L1 Pallas
kernels, is AOT-lowered once by ``aot.py`` to HLO text, and is executed from
rust via PJRT. Python never runs on the request path.

Numerical notes:
- The T x T ridge system is solved with conjugate gradients (fixed 2T
  iterations) rather than ``jnp.linalg.solve``: jax lowers LAPACK solves to
  jaxlib custom-calls that the rust PJRT client (xla_extension 0.5.1) cannot
  resolve; CG lowers to plain HLO while/dot ops and is exact for SPD systems
  within float32 tolerance at these sizes (T <= 64).
- All row dimensions are padded to fixed buckets; masks (w = 0 rows) make
  padding inert. The rust runtime owns the padding (runtime/pad.rs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.combine import combine as combine_kernel
from .kernels.gram import gram as gram_kernel
from .kernels.loglik import loglik as loglik_kernel
from .kernels.predict import predict as predict_kernel


def cg_solve(a: jnp.ndarray, b: jnp.ndarray, iters: int) -> jnp.ndarray:
    """Conjugate-gradient solve of the SPD system a @ x = b (plain-HLO safe)."""

    def body(_, st):
        x, r, p, rs = st
        ap = a @ p
        alpha = rs / (p @ ap + 1e-30)
        x = x + alpha * p
        r2 = r - alpha * ap
        rs2 = r2 @ r2
        beta = rs2 / (rs + 1e-30)
        return (x, r2, r2 + beta * p, rs2)

    x0 = jnp.zeros_like(b)
    x, *_ = lax.fori_loop(0, iters, body, (x0, b, b, b @ b))
    return x


def eta_solve(zbar: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray,
              lam: jnp.ndarray, mu: jnp.ndarray):
    """MAP eta step of the stochastic EM (paper eq. 2).

    Maximizing  -(1/2 rho) sum_d w_d (y_d - eta^T zbar_d)^2
                -(1/2 sigma) sum_t (eta_t - mu)^2
    gives the ridge system  (Z^T W Z + lam I) eta = Z^T W y + lam mu,
    with lam = rho / sigma.

    zbar: [D, T] padded; y, w: [D]; lam, mu: scalars.
    Returns (eta [T], train_mse scalar, wsum scalar).
    """
    t = zbar.shape[1]
    g, b = gram_kernel(zbar, w, y)
    a = g + lam * jnp.eye(t, dtype=zbar.dtype)
    rhs = b + lam * mu
    eta = cg_solve(a, rhs, iters=2 * t)
    yhat = predict_kernel(zbar, eta)
    wsum = jnp.sum(w)
    mse = jnp.sum(w * (y - yhat) ** 2) / (wsum + 1e-12)
    return eta, mse, wsum


def gram_fn(zbar: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray):
    """Chunkable Gram accumulation: G = Z^T W Z, b = Z^T W y, n = sum w.

    For training sets larger than ROW_BUCKET the rust runtime streams row
    chunks through this artifact and sums the (G, b, n) outputs — the T x T
    ridge solve then happens coordinator-side (regress/ridge.rs), keeping
    the data-parallel heavy lifting in XLA without shape explosion.
    """
    g, b = gram_kernel(zbar, w, y)
    return g, b, jnp.sum(w)


def predict_fn(zbar: jnp.ndarray, eta: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray):
    """Batched prediction + masked metrics (paper eq. 5).

    zbar: [B, T] padded; eta: [T]; y, w: [B] (y may be all-zero when labels
    are unknown — rust then ignores mse/acc).
    Returns (yhat [B], mse scalar, acc scalar).
    """
    yhat = predict_kernel(zbar, eta)
    wsum = jnp.sum(w) + 1e-12
    mse = jnp.sum(w * (y - yhat) ** 2) / wsum
    hits = (yhat > 0.5) == (y > 0.5)
    acc = jnp.sum(w * hits.astype(zbar.dtype)) / wsum
    return yhat, mse, acc


def combine_fn(preds: jnp.ndarray, weights: jnp.ndarray):
    """Normalized weighted combination of shard predictions (eqs. 7-9).

    preds: [M, B] padded on both axes; weights: [M] (zero for padding
    shards). Returns (yhat [B], wnorm [M]).
    """
    wn = weights / (jnp.sum(weights) + 1e-30)
    return combine_kernel(preds, wn), wn


def loglik_fn(y: jnp.ndarray, mu: jnp.ndarray, rho: jnp.ndarray):
    """Gaussian response log-density grid (margin term of eq. 1).

    y: [B]; mu: [B, T]; rho: scalar. Returns ll [B, T].
    """
    return loglik_kernel(y, mu, rho)


# ---------------------------------------------------------------------------
# AOT shape buckets. The rust runtime reads these from the manifest; keep in
# sync with rust/src/runtime/manifest.rs expectations.
# ---------------------------------------------------------------------------

ROW_BUCKET = 4096      # padded document rows for eta_solve / predict / loglik
SHARD_BUCKET = 16      # padded shard axis for combine
TOPIC_BUCKETS = (8, 16, 32, 64)

F32 = jnp.float32


def make_specs(t: int):
    """jax.ShapeDtypeStruct argument specs per function, for bucket T = t."""
    d = ROW_BUCKET
    m = SHARD_BUCKET
    s = lambda *shape: jax.ShapeDtypeStruct(shape, F32)  # noqa: E731
    return {
        f"eta_solve_T{t}": (eta_solve, (s(d, t), s(d), s(d), s(), s())),
        f"gram_T{t}": (gram_fn, (s(d, t), s(d), s(d))),
        f"predict_T{t}": (predict_fn, (s(d, t), s(t), s(d), s(d))),
        f"loglik_T{t}": (loglik_fn, (s(d), s(d, t), s())),
    }


def combine_spec():
    s = lambda *shape: jax.ShapeDtypeStruct(shape, F32)  # noqa: E731
    return {f"combine_M{SHARD_BUCKET}": (combine_fn, (s(SHARD_BUCKET, ROW_BUCKET), s(SHARD_BUCKET)))}
