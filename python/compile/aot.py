"""AOT compiler: lower every L2 graph to HLO *text* + write the manifest.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (proto.id() <= INT_MAX); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  python -m python.compile.aot --out artifacts
Run from the repo root (the Makefile's `make artifacts` target does).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name, fn, specs, out_dir):
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    params = [{"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs]
    digest = hashlib.sha256(text.encode()).hexdigest()[:16]
    return {
        "name": name,
        "file": f"{name}.hlo.txt",
        "params": params,
        "sha256_16": digest,
        "hlo_bytes": len(text),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="AOT-lower cfslda L2 graphs to HLO text")
    ap.add_argument("--out", default="artifacts", help="output directory")
    ap.add_argument("--topics", type=int, nargs="*", default=list(model.TOPIC_BUCKETS),
                    help="topic buckets to compile (default: all)")
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)

    entries = []
    for t in args.topics:
        for name, (fn, specs) in model.make_specs(t).items():
            print(f"lowering {name} ...", flush=True)
            entries.append(lower_entry(name, fn, specs, args.out))
    for name, (fn, specs) in model.combine_spec().items():
        print(f"lowering {name} ...", flush=True)
        entries.append(lower_entry(name, fn, specs, args.out))

    manifest = {
        "version": 1,
        "row_bucket": model.ROW_BUCKET,
        "shard_bucket": model.SHARD_BUCKET,
        "topic_buckets": sorted(args.topics),
        "dtype": "f32",
        "functions": entries,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {len(entries)} artifacts + manifest.json to {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
