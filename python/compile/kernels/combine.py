"""Pallas kernel: weighted combination of shard predictions (paper eqs. 7-9).

The combination stage of the communication-free algorithm: given the [M, B]
matrix of local predictions (one row per shard) and per-shard weights, emit
the global prediction sum_m w_m P[m, :]. Weights are normalized by the L2
wrapper (model.combine_fn); the kernel consumes them as-is so Simple Average
is just the uniform-weights special case.

Grid is over B column blocks; the whole shard axis (M <= 16) rides along in
VMEM. interpret=True for CPU-PJRT execution (see gram.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128


def _combine_kernel(p_ref, w_ref, o_ref):
    # p [M, BLK], w [M, 1] -> o [1, BLK]
    o_ref[...] = jnp.sum(p_ref[...] * w_ref[...], axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block",))
def combine(preds: jnp.ndarray, weights: jnp.ndarray, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """sum_m weights[m] * preds[m, :].  preds: [M, B] (B % block == 0) -> [B]."""
    m, b = preds.shape
    assert b % block == 0, f"cols {b} not a multiple of block {block}"
    out = pl.pallas_call(
        _combine_kernel,
        grid=(b // block,),
        in_specs=[
            pl.BlockSpec((m, block), lambda i: (0, i)),
            pl.BlockSpec((m, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, b), preds.dtype),
        interpret=True,
    )(preds, weights[:, None])
    return out[0]
