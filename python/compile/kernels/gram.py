"""Pallas kernel: masked Gram matrix accumulation for the ridge eta-solve.

Computes G = Z^T diag(w) Z (T x T) and b = Z^T diag(w) y (T) by streaming
row-blocks of Z through VMEM and accumulating into a single resident [T, T]
output tile. This is the MXU-shaped hot spot of the stochastic-EM eta step
(paper eq. 2): on TPU each row block is a [BLK, T] x [T, BLK] systolic
contraction; on this image we lower with interpret=True (CPU PJRT cannot run
Mosaic custom-calls) and validate numerics against ``ref.gram_ref``.

TPU sizing (recorded in DESIGN.md / EXPERIMENTS.md §Perf): with BLK=128 and
T<=64 the working set is z-block 128*64*4 = 32 KiB, accumulators <= 16.25 KiB
— far under the ~16 MiB VMEM budget, so the schedule is bandwidth-bound and
double-buffering the z stream hides the HBM latency.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128


def _gram_kernel(z_ref, w_ref, y_ref, g_ref, b_ref):
    """One grid step: fold a [BLK, T] row-block into the G/b accumulators."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        b_ref[...] = jnp.zeros_like(b_ref)

    z = z_ref[...]               # [BLK, T]
    wz = z * w_ref[...]          # mask/weight rows; padding rows contribute 0
    g_ref[...] += wz.T @ z
    b_ref[...] += wz.T @ y_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def gram(zbar: jnp.ndarray, w: jnp.ndarray, y: jnp.ndarray, block: int = DEFAULT_BLOCK):
    """G = Z^T diag(w) Z, b = Z^T diag(w) y via a row-streaming Pallas kernel.

    zbar: [D, T] with D % block == 0 (callers pad; mask padding via w=0)
    w, y: [D]
    returns (G [T, T], b [T])
    """
    d, t = zbar.shape
    assert d % block == 0, f"rows {d} not a multiple of block {block}"
    grid = (d // block,)
    g, b = pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, t), lambda i: (i, 0)),
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((t, t), lambda i: (0, 0)),
            pl.BlockSpec((t, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, t), zbar.dtype),
            jax.ShapeDtypeStruct((t, 1), zbar.dtype),
        ],
        interpret=True,
    )(zbar, w[:, None], y[:, None])
    return g, b[:, 0]
