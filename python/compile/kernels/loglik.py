"""Pallas kernel: fused Gaussian response log-density grid.

The response-margin term N(y_d ; mu_{d,t}, rho) of the collapsed Gibbs
conditional (paper eq. 1), evaluated in log space for a batch of documents
against all T candidate topic means at once. Used by the diagnostics path
(blocked-update scoring, quasi-ergodicity probes). Fusing the subtract /
square / scale chain keeps the [BLK, T] tile in VMEM for a single pass.
interpret=True for CPU-PJRT execution (see gram.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128


def _loglik_kernel(y_ref, mu_ref, rho_ref, o_ref):
    rho = rho_ref[0, 0]
    d = y_ref[...] - mu_ref[...]      # [BLK, 1] - [BLK, T] broadcasts
    o_ref[...] = -0.5 * jnp.log(2.0 * jnp.pi * rho) - d * d / (2.0 * rho)


@functools.partial(jax.jit, static_argnames=("block",))
def loglik(y: jnp.ndarray, mu: jnp.ndarray, rho: jnp.ndarray, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """log N(y_b ; mu_{b,t}, rho).  y: [B], mu: [B, T] (B % block == 0), rho: scalar."""
    b, t = mu.shape
    assert b % block == 0, f"rows {b} not a multiple of block {block}"
    rho2d = jnp.asarray(rho, dtype=mu.dtype).reshape(1, 1)
    return pl.pallas_call(
        _loglik_kernel,
        grid=(b // block,),
        in_specs=[
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
            pl.BlockSpec((block, t), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t), mu.dtype),
        interpret=True,
    )(y[:, None], mu, rho2d)
