"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written in
plain ``jax.numpy`` with no Pallas, no tiling and no tricks. The pytest suite
(``python/tests``) sweeps shapes/seeds with hypothesis and asserts the Pallas
outputs match these oracles to float32 tolerance. The L2 model graphs
(``python/compile/model.py``) are additionally checked against numpy algebra.
"""

from __future__ import annotations

import jax.numpy as jnp


def gram_ref(zbar: jnp.ndarray, w: jnp.ndarray, y: jnp.ndarray):
    """Masked Gram matrix and moment vector.

    G = Z^T diag(w) Z          [T, T]
    b = Z^T diag(w) y          [T]

    zbar: [D, T] empirical topic proportions (rows may be zero padding)
    w:    [D]    row mask / weight (0.0 for padding)
    y:    [D]    responses
    """
    wz = zbar * w[:, None]
    return wz.T @ zbar, wz.T @ y


def predict_ref(zbar: jnp.ndarray, eta: jnp.ndarray) -> jnp.ndarray:
    """yhat = Z eta  (paper eq. 5).  zbar: [B, T], eta: [T] -> [B]."""
    return zbar @ eta


def combine_ref(preds: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """Weighted combination of shard predictions (paper eqs. 7-9).

    preds:   [M, B] local predictions, one row per shard
    weights: [M]    non-negative, NOT necessarily normalized
    returns  [B]    sum_m (w_m / sum w) preds[m]
    """
    wn = weights / (jnp.sum(weights) + 1e-30)
    return wn @ preds


def loglik_ref(y: jnp.ndarray, mu: jnp.ndarray, rho: jnp.ndarray) -> jnp.ndarray:
    """Gaussian response log-density grid (the margin term of paper eq. 1).

    y:   [B]     observed responses
    mu:  [B, T]  candidate means (per doc, per candidate topic)
    rho: scalar  response variance
    returns [B, T] log N(y_b ; mu_{b,t}, rho)
    """
    d = y[:, None] - mu
    return -0.5 * jnp.log(2.0 * jnp.pi * rho) - d * d / (2.0 * rho)
