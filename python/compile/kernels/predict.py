"""Pallas kernel: batched sLDA prediction yhat = Z eta (paper eq. 5).

Streams [BLK, T] blocks of the empirical topic-proportion matrix through
VMEM and emits [BLK] prediction blocks; eta stays VMEM-resident across the
whole grid. interpret=True for CPU-PJRT execution (see gram.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128


def _predict_kernel(z_ref, eta_ref, o_ref):
    o_ref[...] = z_ref[...] @ eta_ref[...]   # [BLK, T] @ [T, 1] -> [BLK, 1]


@functools.partial(jax.jit, static_argnames=("block",))
def predict(zbar: jnp.ndarray, eta: jnp.ndarray, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """yhat = zbar @ eta.  zbar: [B, T] (B % block == 0), eta: [T] -> [B]."""
    b, t = zbar.shape
    assert b % block == 0, f"rows {b} not a multiple of block {block}"
    out = pl.pallas_call(
        _predict_kernel,
        grid=(b // block,),
        in_specs=[
            pl.BlockSpec((block, t), lambda i: (i, 0)),
            pl.BlockSpec((t, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), zbar.dtype),
        interpret=True,
    )(zbar, eta[:, None])
    return out[:, 0]
