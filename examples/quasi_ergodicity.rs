//! Figures 1-3 as measured experiments: why naive posterior pooling fails
//! for topic models and why prediction-space combination fixes it.
//!
//! * Fig 1: pooling sub-chains of a *unimodal* posterior is valid (KS small)
//! * Fig 2: pooling chains stuck in different modes of a *multimodal*
//!   posterior misrepresents it (KS large, basin masses wrong)
//! * Fig 3: sLDA shards land in different topic-permutation modes
//!   (Hungarian permutation gap) yet their 1-D predictions agree
//!
//!     cargo run --release --example quasi_ergodicity

use cfslda::config::schema::ExperimentConfig;
use cfslda::data::partition::train_test_split;
use cfslda::data::synthetic::{generate_corpus, SyntheticSpec};
use cfslda::experiments::fig123;
use cfslda::runtime::EngineHandle;
use cfslda::util::rng::Pcg64;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    cfslda::util::logging::init();
    let seed = 20170710u64;

    let f1 = fig123::fig1_unimodal(3, 20_000, seed);
    let f2 = fig123::fig2_multimodal(20_000, seed);

    let spec = SyntheticSpec::continuous_small();
    let mut rng = Pcg64::seed_from_u64(seed);
    let corpus = generate_corpus(&spec, &mut rng);
    let ds = train_test_split(&corpus, spec.docs * 3 / 4, &mut rng);
    let mut cfg = ExperimentConfig::quick();
    cfg.seed = seed;
    let dir = std::env::var("CFSLDA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let engine = EngineHandle::from_kind(cfg.engine, Path::new(&dir))?;
    let f3 = fig123::fig3_projection(&ds, &cfg, &engine)?;

    println!("{}", fig123::render(&f1, &f2, &f3));

    // Machine-checkable verdicts (the paper's qualitative claims):
    anyhow::ensure!(f1.ks_pooled < 0.05, "Fig 1 violated: pooled KS {}", f1.ks_pooled);
    anyhow::ensure!(f2.ks_pooled > 0.2, "Fig 2 violated: pooled KS {}", f2.ks_pooled);
    anyhow::ensure!(
        f3.modes.permutation_gap() > 0.03,
        "Fig 3 violated: no permutation gap"
    );
    anyhow::ensure!(
        f3.prediction_corr_mean > 0.5,
        "Fig 3 violated: local predictions disagree"
    );
    println!("all three figure claims verified ✓");
    Ok(())
}
