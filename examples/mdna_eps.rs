//! Paper Experiment I (Fig 6): MD&A text -> earnings per share.
//!
//! Runs the four-algorithm comparison on the Experiment-I-scale synthetic
//! corpus (4216 docs, 4238-term vocabulary, continuous near-normal labels;
//! DESIGN.md §3 documents the data substitution) and prints the Fig-6 table:
//! computation time and test MSE per algorithm.
//!
//!     cargo run --release --example mdna_eps -- [--scale 1.0] [--runs 3]
//!         [--iters 100] [--engine auto|xla|native] [--check]

use cfslda::cli::args::Args;
use cfslda::config::schema::EngineKind;
use cfslda::experiments::runner::{check_fig_shape, render_table, run_comparison, Comparison};
use cfslda::runtime::EngineHandle;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    cfslda::util::logging::init();
    let args = Args::parse(std::env::args().skip(1))?;
    let scale = args.get_f64("scale", 0.25)?;
    let runs = args.get_usize("runs", 3)?;
    let iters = args.get_usize("iters", 60)?;

    let mut c = Comparison::fig6(scale, runs);
    c.cfg.engine = EngineKind::parse(args.get_or("engine", "auto"))?;
    c.cfg.train.sweeps = iters;
    c.cfg.train.burnin = (iters / 10).max(2);
    c.cfg.train.eta_every = 5;

    let dir = std::env::var("CFSLDA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let engine = EngineHandle::from_kind(c.cfg.engine, Path::new(&dir))?;
    println!(
        "Experiment I: docs={} vocab={} topics={} sweeps={} shards={} engine={} runs={}",
        c.spec.docs,
        c.spec.vocab,
        c.cfg.model.topics,
        c.cfg.train.sweeps,
        c.cfg.parallel.shards,
        engine.name(),
        runs
    );
    let (series, _) = run_comparison(&c, &engine)?;
    println!(
        "{}",
        render_table(
            &format!("Fig 6: MD&A -> EPS (synthetic, scale {scale})"),
            &series,
            false
        )
    );
    if args.has("check") {
        check_fig_shape(&series, false)?;
        println!("Fig-6 shape check PASSED");
    }
    Ok(())
}
