//! Paper Experiment II (Fig 7): movie reviews -> binary sentiment.
//!
//! Four-algorithm comparison on the Experiment-II-scale synthetic corpus
//! (25k docs at full scale, binary labels via the paper's logit-normal
//! note). Prints the Fig-7 table: computation time and test accuracy.
//!
//!     cargo run --release --example imdb_sentiment -- [--docs 25000]
//!         [--runs 3] [--iters 60] [--engine auto|xla|native] [--check]

use cfslda::cli::args::Args;
use cfslda::config::schema::EngineKind;
use cfslda::experiments::runner::{check_fig_shape, render_table, run_comparison, Comparison};
use cfslda::runtime::EngineHandle;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    cfslda::util::logging::init();
    let args = Args::parse(std::env::args().skip(1))?;
    let docs = args.get_usize("docs", 6000)?;
    let runs = args.get_usize("runs", 3)?;
    let iters = args.get_usize("iters", 50)?;

    let scale = docs as f64 / 25_000.0;
    let mut c = Comparison::fig7(scale, runs);
    c.cfg.engine = EngineKind::parse(args.get_or("engine", "auto"))?;
    c.cfg.train.sweeps = iters;
    c.cfg.train.burnin = (iters / 10).max(2);

    let dir = std::env::var("CFSLDA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let engine = EngineHandle::from_kind(c.cfg.engine, Path::new(&dir))?;
    println!(
        "Experiment II: docs={} vocab={} topics={} sweeps={} shards={} engine={} runs={}",
        c.spec.docs,
        c.spec.vocab,
        c.cfg.model.topics,
        c.cfg.train.sweeps,
        c.cfg.parallel.shards,
        engine.name(),
        runs
    );
    let (series, _) = run_comparison(&c, &engine)?;
    println!(
        "{}",
        render_table(
            &format!("Fig 7: reviews -> sentiment (synthetic, {} docs)", c.spec.docs),
            &series,
            true
        )
    );
    if args.has("check") {
        check_fig_shape(&series, true)?;
        println!("Fig-7 shape check PASSED");
    }
    Ok(())
}
