//! End-to-end validation driver (DESIGN.md deliverable (b)/EXPERIMENTS.md
//! §E2E): exercises every layer of the stack on a real small workload and
//! proves they compose:
//!
//!   synthetic corpus (sLDA generative process, paper Exp-I protocol)
//!     -> disk round-trip (BoW format)
//!     -> 4-shard communication-free training (L3 Gibbs hot path)
//!     -> stochastic-EM eta solves through the AOT XLA artifacts
//!        (L2 JAX graphs wrapping the L1 Pallas gram/predict kernels, via
//!        PJRT; falls back to native with a warning if artifacts are absent)
//!     -> local predictions -> Simple/Weighted combination (combine artifact)
//!     -> metrics + convergence log + quasi-ergodicity diagnostic
//!
//!     cargo run --release --example e2e_pipeline -- [--docs 2000] [--iters 40]

use cfslda::cli::args::Args;
use cfslda::config::schema::{EngineKind, ExperimentConfig};
use cfslda::data::loader;
use cfslda::data::partition::train_test_split;
use cfslda::data::stats::label_report;
use cfslda::data::synthetic::{generate_corpus, SyntheticSpec};
use cfslda::eval::mode_diag::mode_divergence;
use cfslda::parallel::leader::{run_with_engine, Algorithm};
use cfslda::runtime::EngineHandle;
use cfslda::sampler::gibbs_train;
use cfslda::util::rng::Pcg64;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    cfslda::util::logging::init();
    let args = Args::parse(std::env::args().skip(1))?;
    let docs = args.get_usize("docs", 2000)?;
    let iters = args.get_usize("iters", 40)?;

    // --- stage 1: data ---------------------------------------------------
    let mut spec = SyntheticSpec::mdna();
    spec.docs = docs;
    spec.vocab = (docs.max(400)).min(4238);
    let mut rng = Pcg64::seed_from_u64(20170710);
    let corpus = generate_corpus(&spec, &mut rng);
    println!("[1/5] corpus: {} docs, vocab {}, {} tokens",
             corpus.num_docs(), corpus.vocab_size, corpus.num_tokens());
    let report = label_report(&corpus, 20);
    println!("      labels: mean={:.3} std={:.3} KS-vs-normal={:.4}",
             report.summary.mean(), report.summary.std(), report.ks_normal);

    // --- stage 2: disk round-trip -----------------------------------------
    let bow = std::env::temp_dir().join(format!("cfslda_e2e_{}.bow", std::process::id()));
    loader::save_bow(&corpus, &bow)?;
    let corpus = loader::load_bow(&bow)?;
    std::fs::remove_file(&bow).ok();
    println!("[2/5] disk round-trip OK ({} docs)", corpus.num_docs());

    // --- stage 3: engine (AOT artifacts preferred) ------------------------
    let dir = std::env::var("CFSLDA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let engine = EngineHandle::from_kind(EngineKind::Auto, Path::new(&dir))?;
    println!("[3/5] engine: {} (artifacts dir: {dir})", engine.name());

    // --- stage 4: training with convergence log ---------------------------
    let n_train = docs * 3 / 4;
    let ds = train_test_split(&corpus, n_train, &mut rng);
    let mut cfg = ExperimentConfig::fig6();
    cfg.train.sweeps = iters;
    cfg.train.burnin = (iters / 10).max(2);
    cfg.train.eta_every = 5;
    cfg.model.topics = 16;

    let mut train_rng = Pcg64::seed_from_u64(cfg.seed);
    let single = gibbs_train::train(&ds.train, &cfg, &engine, &mut train_rng)?;
    println!("[4/5] non-parallel training convergence (train MSE per eta step):");
    for h in &single.history {
        println!("      sweep {:>4}  mse {:.4}  rho {:.4}  |eta| {:.3}",
                 h.sweep, h.train_mse, h.rho, h.eta_l2);
    }
    let first = single.history.first().map(|h| h.train_mse).unwrap_or(0.0);
    let last = single.history.last().map(|h| h.train_mse).unwrap_or(0.0);
    anyhow::ensure!(last < first, "training MSE did not improve: {first} -> {last}");

    // --- stage 5: the four algorithms + diagnostics ------------------------
    println!("[5/5] four-algorithm comparison:");
    let mut naive_mse = f64::NAN;
    let mut simple_mse = f64::NAN;
    for algo in Algorithm::ALL {
        let keep = algo == Algorithm::SimpleAverage;
        let (out, models) = run_with_engine(algo, &ds, &cfg, &engine, keep)?;
        println!(
            "      {:<18} wall={:>7.2}s  mse={:.4}  r2={:+.3}  comm[{}]",
            algo.name(), out.wall_secs, out.test_metrics.mse, out.test_metrics.r2,
            out.comm.render()
        );
        match algo {
            Algorithm::NaiveCombination => naive_mse = out.test_metrics.mse,
            Algorithm::SimpleAverage => {
                simple_mse = out.test_metrics.mse;
                let phis: Vec<_> = models.iter().map(|m| m.phi_topic_rows()).collect();
                let div = mode_divergence(&phis);
                println!(
                    "      quasi-ergodicity probe: identity TV {:.3}, aligned TV {:.3}, gap {:.3}",
                    div.mean_identity, div.mean_aligned, div.permutation_gap()
                );
            }
            _ => {}
        }
    }
    anyhow::ensure!(
        naive_mse > simple_mse,
        "expected naive ({naive_mse}) worse than simple ({simple_mse})"
    );
    println!("\nE2E PIPELINE OK — all layers compose; naive({naive_mse:.4}) > simple({simple_mse:.4}) as the paper predicts");
    Ok(())
}
