//! Quickstart: generate a small synthetic sLDA corpus, train with the
//! communication-free Simple Average algorithm, and report test MSE.
//!
//!     cargo run --release --example quickstart

use cfslda::config::schema::ExperimentConfig;
use cfslda::data::synthetic::{generate_split, SyntheticSpec};
use cfslda::parallel::leader::{run_algorithm, Algorithm};
use cfslda::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    cfslda::util::logging::init();

    // 1. A small corpus drawn from the sLDA generative process: 800 docs,
    //    600-term vocabulary, continuous (EPS-like) responses. Big enough
    //    that each of the 4 shards still sees a useful sample.
    let mut spec = SyntheticSpec::continuous_small();
    spec.docs = 800;
    spec.vocab = 600;
    let mut rng = Pcg64::seed_from_u64(7);
    let ds = generate_split(&spec, 600, &mut rng);
    println!(
        "corpus: {} train docs, {} test docs, vocab {}",
        ds.train.num_docs(),
        ds.test.num_docs(),
        ds.train.vocab_size
    );

    // 2. Train + predict with the paper's Simple Average algorithm
    //    (M = 4 communication-free Gibbs chains, predictions averaged).
    let cfg = ExperimentConfig::quick();
    let out = run_algorithm(Algorithm::SimpleAverage, &ds, &cfg)?;

    // 3. Compare against the non-parallel baseline.
    let base = run_algorithm(Algorithm::NonParallel, &ds, &cfg)?;

    // machine time = simulated M-core wall (this container has 1 core;
    // see DESIGN.md §3) — the clock the paper's comparisons use.
    println!(
        "\nsimple-average : machine time {:.2}s {}",
        out.sim_wall_secs,
        out.test_metrics.render(false)
    );
    println!(
        "non-parallel   : machine time {:.2}s {}",
        base.sim_wall_secs,
        base.test_metrics.render(false)
    );
    println!(
        "\nspeedup {:.2}x, MSE ratio {:.3}, sampling-phase communication: {} sync events",
        base.sim_wall_secs / out.sim_wall_secs,
        out.test_metrics.mse / base.test_metrics.mse,
        out.comm.sampling_syncs
    );
    Ok(())
}
